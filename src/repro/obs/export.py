"""Trace + time-series export (DESIGN.md §16).

Two consumers, two formats:

  * ``chrome_trace`` — a Chrome-trace/Perfetto JSON object
    (``chrome://tracing`` / ui.perfetto.dev both load it) fusing every
    temporal artifact one run produces: sampled per-tuple spans (§12)
    as nested slices on per-operator tracks, engine events (epoch
    barriers, migrations, failures/recoveries, window fires) as slices
    and instants on a control track, health alerts as slices spanning
    raise->clear, and timeline series as counter tracks.  All times are
    the sim's logical clock scaled to microseconds (the trace viewer's
    native unit).
  * ``timeline_jsonl`` — one line per timeline interval (the
    ``Interval.as_record`` shape) plus one line per alert, the input
    ``tools/obs_report.py --timeline`` renders and ``--since/--until``
    filter.

Stdlib-only, like the rest of the obs plane.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List, Optional

from repro.obs.health import Alert
from repro.obs.timeseries import Timeline

# fixed virtual pids: one per track family, so the viewer groups them
PID_SPANS = 1
PID_CONTROL = 2
PID_COUNTERS = 3

# timeline series promoted to counter tracks (gauge name -> track name);
# <op> expands per operator seen in the intervals
COUNTER_TRACKS = (
    ("engine.<op>.queue.depth", "queue depth"),
    ("engine.<op>.watermark.lag", "watermark lag (s)"),
    ("engine.<op>.fused.fill_ratio", "fused fill ratio"),
)


def _us(t: float) -> int:
    return int(round(t * 1e6))


def _meta(pid: int, name: str, tid: Optional[int] = None,
          tname: Optional[str] = None) -> List[dict]:
    out = [{"ph": "M", "pid": pid, "name": "process_name",
            "args": {"name": name}}]
    if tid is not None:
        out.append({"ph": "M", "pid": pid, "tid": tid,
                    "name": "thread_name", "args": {"name": tname}})
    return out


def _span_events(spans: Iterable[Dict[str, Any]]) -> List[dict]:
    """Sampled tuple spans -> nested slices: the whole tuple as the
    outer slice, its stages laid out inside it.  ``sync_fetch`` measures
    pipeline blocking, not a slice of this tuple's latency (trace.py),
    so it renders as an instant annotation rather than a sub-slice."""
    evs: List[dict] = []
    tids: Dict[str, int] = {}
    for rec in spans:
        op = rec.get("op") or "?"
        tid = tids.setdefault(op, len(tids) + 1)
        t0, t_sink = rec["t0"], rec["t_sink"]
        if t_sink < t0:
            continue
        hit = rec.get("hit")
        evs.append({"ph": "X", "pid": PID_SPANS, "tid": tid,
                    "name": "tuple", "cat": "span",
                    "ts": _us(t0), "dur": max(1, _us(t_sink - t0)),
                    "args": {"hit": hit,
                             "sync_fetch_s": rec.get("sync_fetch", 0.0)}})
        cur = t0
        for stage in ("upstream", "park_wait", "downstream"):
            d = rec.get(stage, 0.0)
            if d <= 0.0:
                continue
            if stage == "downstream":
                start = max(cur, t_sink - d)
            else:
                start = cur
            evs.append({"ph": "X", "pid": PID_SPANS, "tid": tid,
                        "name": stage, "cat": "stage",
                        "ts": _us(start), "dur": max(1, _us(d))})
            cur = start + d
        sf = rec.get("sync_fetch", 0.0)
        if sf > 0.0:
            evs.append({"ph": "i", "pid": PID_SPANS, "tid": tid,
                        "name": f"sync_fetch {sf*1e3:.2f}ms",
                        "cat": "stage", "ts": _us(t_sink), "s": "t"})
    meta = _meta(PID_SPANS, "tuple spans")
    for op, tid in tids.items():
        meta += [{"ph": "M", "pid": PID_SPANS, "tid": tid,
                  "name": "thread_name", "args": {"name": op}}]
    return meta + evs


# engine event kinds that OPEN a slice and the kind that closes it
_PAIRED = {"epoch_trigger": ("epoch_complete", "epoch", 1),
           "migrate_begin": ("migrate_end", "migration", 2),
           "failure": ("recovered", "recovery", 3)}
_TID_FIRES = 4
_TID_ALERTS = 5


def _control_events(events: Iterable[tuple]) -> List[dict]:
    """Engine event log -> control-track slices/instants.  Events are
    ``(kind, t, fields)``; paired kinds (epoch trigger/complete,
    migrate begin/end, failure/recovered) become duration slices matched
    by their correlation field, window fires become instants."""
    evs: List[dict] = []
    open_by_key: Dict[tuple, tuple] = {}
    for kind, t, fields in events:
        if kind in _PAIRED:
            close_kind, name, tid = _PAIRED[kind]
            key = (close_kind, fields.get("id"))
            open_by_key[key] = (t, name, tid, dict(fields))
        elif any(kind == ck for ck, _, _ in _PAIRED.values()):
            key = (kind, fields.get("id"))
            opened = open_by_key.pop(key, None)
            if opened is None:
                continue                 # close without open (pre-export)
            t0, name, tid, args = opened
            args.update(fields)
            evs.append({"ph": "X", "pid": PID_CONTROL, "tid": tid,
                        "name": name, "cat": "control", "ts": _us(t0),
                        "dur": max(1, _us(t - t0)), "args": args})
        elif kind == "fire":
            evs.append({"ph": "i", "pid": PID_CONTROL, "tid": _TID_FIRES,
                        "name": "fire", "cat": "control", "ts": _us(t),
                        "s": "t", "args": dict(fields)})
    # unterminated opens (an epoch in flight at export) render to run end
    for (_, _id), (t0, name, tid, args) in open_by_key.items():
        evs.append({"ph": "i", "pid": PID_CONTROL, "tid": tid,
                    "name": f"{name} (open)", "cat": "control",
                    "ts": _us(t0), "s": "t", "args": args})
    meta = _meta(PID_CONTROL, "control plane")
    for name, tid in (("epochs", 1), ("migrations", 2),
                      ("recoveries", 3), ("fires", _TID_FIRES),
                      ("alerts", _TID_ALERTS)):
        meta.append({"ph": "M", "pid": PID_CONTROL, "tid": tid,
                     "name": "thread_name", "args": {"name": name}})
    return meta + evs


def _alert_events(alerts: Iterable[Alert], t_end: float) -> List[dict]:
    evs: List[dict] = []
    for a in alerts:
        t1 = a.cleared_t if a.cleared_t is not None else t_end
        evs.append({"ph": "X", "pid": PID_CONTROL, "tid": _TID_ALERTS,
                    "name": f"ALERT {a.kind}", "cat": "health",
                    "ts": _us(a.t), "dur": max(1, _us(t1 - a.t)),
                    "args": a.as_dict()})
    return evs


def _counter_events(timeline: Timeline) -> List[dict]:
    evs: List[dict] = list(_meta(PID_COUNTERS, "timeline"))
    ops = set()
    for iv in timeline.ring:
        for g in iv.gauges:
            if g.startswith("engine.") and g.endswith(".queue.depth"):
                ops.add(g.split(".")[1])
    for iv in timeline.ring:
        for tmpl, track in COUNTER_TRACKS:
            for op in sorted(ops):
                name = tmpl.replace("<op>", op)
                if name in iv.gauges:
                    evs.append({"ph": "C", "pid": PID_COUNTERS, "tid": 0,
                                "name": f"{op} {track}",
                                "ts": _us(iv.t1),
                                "args": {"value": iv.gauges[name]}})
        d = iv.deltas.get("engine.sink.count")
        if d is not None:
            span = max(1e-9, iv.t1 - iv.t0)
            evs.append({"ph": "C", "pid": PID_COUNTERS, "tid": 0,
                        "name": "sink throughput (tup/s)",
                        "ts": _us(iv.t1), "args": {"value": d / span}})
    return evs


def chrome_trace(engine, path: Optional[str] = None) -> Dict[str, Any]:
    """Build (and optionally write) the Chrome-trace JSON for a run:
    tracer spans + engine events + health alerts + timeline counters.
    Safe on partially-enabled runs — absent planes contribute nothing.
    """
    events: List[dict] = []
    tracer = getattr(engine, "tracer", None)
    if tracer is not None and tracer.spans:
        events += _span_events(tracer.spans)
    events += _control_events(getattr(engine, "events", ()))
    t_end = engine.sim.t
    health = getattr(engine, "health", None)
    if health is not None and health.alerts:
        events += _alert_events(health.alerts, t_end)
    timeline = getattr(engine, "timeline", None)
    if timeline is not None and timeline.ring:
        events += _counter_events(timeline)
    doc = {"traceEvents": events, "displayTimeUnit": "ms",
           "otherData": {"clock": "sim-logical",
                         "t_end_s": t_end,
                         "source": "repro.obs.export.chrome_trace"}}
    if path is not None:
        with open(path, "w") as f:
            json.dump(doc, f)
    return doc


def timeline_jsonl(timeline: Timeline, path: str,
                   alerts: Optional[Iterable[Alert]] = None,
                   append: bool = False) -> int:
    """Write the retained intervals (+ alerts) as JSONL; returns the
    line count.  Interval lines are ``Interval.as_record`` dicts, alert
    lines are ``{"alert": {...}}`` — both carry logical timestamps, so
    downstream filters never diff snapshots by hand."""
    n = 0
    with open(path, "a" if append else "w") as f:
        for iv in timeline.ring:
            f.write(json.dumps(iv.as_record(), sort_keys=True) + "\n")
            n += 1
        for a in (alerts or ()):
            f.write(json.dumps({"alert": a.as_dict()},
                               sort_keys=True) + "\n")
            n += 1
    return n


def read_timeline_jsonl(path: str):
    """Parse a ``timeline_jsonl`` file back into (interval records,
    alert records), preserving order."""
    intervals: List[dict] = []
    alerts: List[dict] = []
    with open(path) as f:
        for raw in f:
            raw = raw.strip()
            if not raw:
                continue
            rec = json.loads(raw)
            if "alert" in rec:
                alerts.append(rec["alert"])
            else:
                intervals.append(rec)
    return intervals, alerts
