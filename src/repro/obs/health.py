"""Health detectors over the timeline, with hysteresis (DESIGN.md §16).

A raw threshold on a noisy series flaps: one interval above, one below,
an alert storm that trains operators to ignore the channel.  Every
detector here is a two-threshold, two-count state machine instead:

    ok      --[value past FIRE threshold for fire_after consecutive
               intervals]-->                                    firing
    firing  --[value past CLEAR threshold for clear_after consecutive
               intervals]-->                                    ok

with ``fire`` strictly tighter than ``clear`` (a gap the noise must
cross twice), so a series oscillating around either single threshold
raises at most one alert — the property ``tests/test_timeline.py``
checks with hypothesis.  Intervals whose supporting volume is below
``min_volume`` (e.g. a precision ratio over 3 stagings) don't advance
either count: low-traffic intervals carry no evidence.

``HealthMonitor`` wires the default detector set over the catalogued
series (watermark-lag growth, queue-depth stall, prefetch-precision
collapse, late-staging-wall onset, migration/recovery spikes, load
shifts) and emits typed ``Alert`` events on the same logical clock the
timeline cuts on.  The chaos harness (streaming/chaos.py) turns seeded
fault schedules into ground truth for these alerts — the alert oracle
gated in BENCH_obs.json.
"""
from __future__ import annotations

import statistics
from typing import Any, Callable, Dict, List, Optional

from repro.obs.timeseries import Interval, Timeline


class Alert:
    """One typed health event on the logical clock.  ``raised`` alerts
    get ``cleared_t`` stamped when their detector returns to ok."""

    __slots__ = ("kind", "op", "t", "value", "threshold", "message",
                 "cleared_t")

    def __init__(self, kind: str, op: Optional[str], t: float,
                 value: float, threshold: float, message: str):
        self.kind = kind
        self.op = op
        self.t = t
        self.value = value
        self.threshold = threshold
        self.message = message
        self.cleared_t: Optional[float] = None

    def as_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "op": self.op, "t": self.t,
                "value": self.value, "threshold": self.threshold,
                "message": self.message, "cleared_t": self.cleared_t}

    def __repr__(self):
        state = "" if self.cleared_t is None \
            else f" cleared@{self.cleared_t:.3f}"
        return (f"Alert({self.kind}@{self.t:.3f} op={self.op} "
                f"value={self.value:.4g}{state})")


class Detector:
    """Hysteresis threshold detector over one scalar series.

    ``direction="above"`` fires when the value exceeds ``fire`` and
    clears below ``clear`` (``fire > clear``); ``direction="below"``
    fires under ``fire`` and clears above ``clear`` (``fire < clear``).
    ``update`` returns a new ``Alert`` exactly on the ok->firing edge.
    """

    def __init__(self, kind: str, fire: float, clear: float,
                 direction: str = "above", fire_after: int = 2,
                 clear_after: int = 2, op: Optional[str] = None):
        if direction not in ("above", "below"):
            raise ValueError(f"direction {direction!r}")
        if direction == "above" and not fire > clear:
            raise ValueError("hysteresis needs fire > clear")
        if direction == "below" and not fire < clear:
            raise ValueError("hysteresis needs fire < clear")
        if fire_after < 1 or clear_after < 1:
            raise ValueError("fire_after/clear_after must be >= 1")
        self.kind = kind
        self.op = op
        self.fire = fire
        self.clear = clear
        self.direction = direction
        self.fire_after = fire_after
        self.clear_after = clear_after
        self.firing = False
        self._hot = 0                   # consecutive fire-side intervals
        self._cool = 0                  # consecutive clear-side intervals
        self.active: Optional[Alert] = None

    def _past_fire(self, v: float) -> bool:
        return v > self.fire if self.direction == "above" else v < self.fire

    def _past_clear(self, v: float) -> bool:
        return v < self.clear if self.direction == "above" \
            else v > self.clear

    def update(self, t: float, value: Optional[float]) -> Optional[Alert]:
        """Advance one interval; ``value=None`` (no evidence) freezes
        both counts."""
        if value is None:
            return None
        if not self.firing:
            self._hot = self._hot + 1 if self._past_fire(value) else 0
            if self._hot >= self.fire_after:
                self.firing = True
                self._hot = 0
                self._cool = 0
                cmp = ">" if self.direction == "above" else "<"
                self.active = Alert(
                    self.kind, self.op, t, value, self.fire,
                    f"{self.kind}: {value:.4g} {cmp} {self.fire:.4g} "
                    f"for {self.fire_after} intervals")
                return self.active
        else:
            self._cool = self._cool + 1 if self._past_clear(value) else 0
            if self._cool >= self.clear_after:
                self.firing = False
                self._cool = 0
                if self.active is not None:
                    self.active.cleared_t = t
                    self.active = None
        return None


class SpikeDetector:
    """Edge detector for rare-event counters (migrations, recoveries):
    any positive interval delta raises one alert per burst; the burst
    closes after ``clear_after`` quiet intervals, so N migrations inside
    one window raise one alert, not N."""

    def __init__(self, kind: str, op: Optional[str] = None,
                 clear_after: int = 2):
        self.kind = kind
        self.op = op
        self.clear_after = clear_after
        self.firing = False
        self._quiet = 0
        self.active: Optional[Alert] = None

    def update(self, t: float, delta: Optional[float]) -> Optional[Alert]:
        if delta is None:
            delta = 0.0
        if delta > 0:
            self._quiet = 0
            if not self.firing:
                self.firing = True
                self.active = Alert(
                    self.kind, self.op, t, delta, 0.0,
                    f"{self.kind}: +{delta:g} in interval")
                return self.active
        elif self.firing:
            self._quiet += 1
            if self._quiet >= self.clear_after:
                self.firing = False
                if self.active is not None:
                    self.active.cleared_t = t
                    self.active = None
        return None


class LoadShiftDetector:
    """Throughput-shift detector: the interval's delivered count
    against the median of the trailing ``window`` intervals.  Fires when
    the ratio leaves [1/band, band] for ``fire_after`` consecutive
    intervals; clears inside the narrower band.  The baseline FREEZES
    while firing (otherwise the shifted rate becomes the new normal and
    the alert clears on its own)."""

    def __init__(self, kind: str = "load_shift", band: float = 1.6,
                 clear_band: float = 1.25, window: int = 8,
                 fire_after: int = 2, clear_after: int = 2,
                 min_volume: float = 20.0, op: Optional[str] = None):
        if not band > clear_band > 1.0:
            raise ValueError("need band > clear_band > 1.0")
        self.kind = kind
        self.op = op
        self.band = band
        self.clear_band = clear_band
        self.window = window
        self.fire_after = fire_after
        self.clear_after = clear_after
        self.min_volume = min_volume
        self.history: List[float] = []
        self.firing = False
        self._hot = 0
        self._cool = 0
        self.active: Optional[Alert] = None

    def update(self, t: float, count: Optional[float]) -> Optional[Alert]:
        if count is None:
            return None
        if len(self.history) < max(2, self.window // 2):
            self.history.append(count)
            return None
        base = statistics.median(self.history)
        if base < self.min_volume:
            # too quiet to define "normal" — keep learning, never fire
            self.history.append(count)
            del self.history[:-self.window]
            return None
        ratio = count / base
        shifted = ratio > self.band or ratio < 1.0 / self.band
        inside = 1.0 / self.clear_band < ratio < self.clear_band
        out = None
        if not self.firing:
            self._hot = self._hot + 1 if shifted else 0
            if self._hot >= self.fire_after:
                self.firing = True
                self._hot = self._cool = 0
                self.active = Alert(
                    self.kind, self.op, t, ratio, self.band,
                    f"load shift: x{ratio:.2f} of trailing median "
                    f"{base:.0f}/interval")
                out = self.active
            else:
                self.history.append(count)
                del self.history[:-self.window]
        else:
            self._cool = self._cool + 1 if inside else 0
            if self._cool >= self.clear_after:
                self.firing = False
                self._cool = 0
                if self.active is not None:
                    self.active.cleared_t = t
                    self.active = None
                self.history.append(count)
                del self.history[:-self.window]
        return out


# detector kinds the chaos alert oracle maps injected faults onto
# (streaming/chaos.py): failure -> recovery, migrate -> migration,
# load_shift -> load_shift
ORACLE_KINDS = {"failure": "recovery", "migrate": "migration",
                "load_shift": "load_shift"}


class HealthMonitor:
    """The default detector set over a ``Timeline``, per stateful
    operator where the signal is operator-scoped.  ``observe(interval)``
    advances every detector one step and returns (and retains) the
    alerts raised on that cut.  Thresholds are constructor arguments so
    tests and the chaos bench can tighten or relax them; the defaults
    are calibrated to stay silent on the golden chaos run
    (DESIGN.md §16's soundness condition)."""

    def __init__(self, timeline: Timeline, ops: List[str],
                 registry=None,
                 wm_lag_fire: float = 1.0, wm_lag_clear: float = 0.5,
                 queue_fire: float = 256.0, queue_clear: float = 64.0,
                 precision_fire: float = 0.30,
                 precision_clear: float = 0.45,
                 late_wall_fire: float = 0.35,
                 late_wall_clear: float = 0.20,
                 min_volume: float = 12.0,
                 load_band: float = 1.6, fire_after: int = 2):
        self.timeline = timeline
        self.ops = list(ops)
        self.registry = registry if registry is not None \
            else timeline.registry
        self.min_volume = min_volume
        self.alerts: List[Alert] = []
        self.detectors: List[Any] = []
        self._extract: Dict[int, Callable[[Interval], Optional[float]]] = {}

        def add(det, fn):
            self.detectors.append(det)
            self._extract[id(det)] = fn

        def gauge_of(name):
            return lambda iv, n=name: iv.gauges.get(n)

        def delta_of(name):
            return lambda iv, n=name: iv.deltas.get(n, 0.0)

        for op in self.ops:
            pre = f"engine.{op}"
            add(Detector("wm_lag", wm_lag_fire, wm_lag_clear,
                         fire_after=fire_after, op=op),
                gauge_of(f"{pre}.watermark.lag"))
            add(Detector("stall", queue_fire, queue_clear,
                         fire_after=fire_after, op=op),
                gauge_of(f"{pre}.queue.depth"))
            add(Detector("precision", precision_fire, precision_clear,
                         direction="below", fire_after=fire_after, op=op),
                self._ratio(f"{pre}.prefetch.used",
                            (f"{pre}.prefetch.staged",
                             f"{pre}.prefetch.late")))
            add(Detector("late_wall", late_wall_fire, late_wall_clear,
                         fire_after=fire_after, op=op),
                self._ratio(f"{pre}.prefetch.late",
                            (f"{pre}.prefetch.staged",
                             f"{pre}.prefetch.late")))
            add(SpikeDetector("migration", op=op),
                delta_of(f"{pre}.shards.migrations"))
            # load shift watches the operator's PROCESSED delta, not the
            # sink count: windowed sinks emit in fire bursts whose
            # per-interval rate whipsaws on a perfectly healthy run,
            # while the input side tracks the source rate smoothly
            add(LoadShiftDetector(band=load_band, fire_after=fire_after,
                                  min_volume=max(min_volume, 50.0),
                                  op=op),
                delta_of(f"{pre}.processed"))
        add(SpikeDetector("recovery"), delta_of("recovery.count"))
        # health-plane instruments (catalogued: health.*)
        self._c_raised = self.registry.counter("health.alerts.raised")
        self._c_cleared = self.registry.counter("health.alerts.cleared")
        self._g_active = self.registry.gauge("health.alerts.active")

    def _ratio(self, num: str, den: tuple
               ) -> Callable[[Interval], Optional[float]]:
        def fn(iv: Interval) -> Optional[float]:
            d = sum(iv.deltas.get(n, 0.0) for n in den)
            if d < self.min_volume:
                return None             # no evidence this interval
            return iv.deltas.get(num, 0.0) / d
        return fn

    def observe(self, iv: Interval) -> List[Alert]:
        new: List[Alert] = []
        for det in self.detectors:
            a = det.update(iv.t1, self._extract[id(det)](iv))
            if a is not None:
                new.append(a)
        self.alerts.extend(new)
        if new:
            self._c_raised.set(len(self.alerts))
        for a in new:
            self.registry.counter(f"health.alerts.{a.kind}").inc()
        cleared = sum(1 for a in self.alerts if a.cleared_t is not None)
        self._c_cleared.set(cleared)
        self._g_active.set(sum(1 for d in self.detectors if d.firing))
        return new

    # ------------------------------------------------------------- summary
    def by_kind(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for a in self.alerts:
            out[a.kind] = out.get(a.kind, 0) + 1
        return out

    def block(self) -> Dict[str, Any]:
        return {"raised": len(self.alerts),
                "cleared": sum(1 for a in self.alerts
                               if a.cleared_t is not None),
                "active": sum(1 for d in self.detectors if d.firing),
                "by_kind": self.by_kind()}
