"""Model builders: every assigned architecture exposes the same API.

    model = build_model(cfg)
    loss, metrics   = model.train_loss(params, batch)
    logits, cache   = model.prefill(params, batch)
    logits, cache   = model.decode(params, cache, batch)

Layer stacks are scanned (stacked leading L dim) so 60-layer models lower to
compact HLO; the loss is computed with a sequence-chunked vocab projection so
[B,S,V] logits are never materialised (V up to 256k).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.sharding import constraint
from repro.models import ssm as ssm_mod
from repro.models.layers import (attention, attention_decode, bf16_grad,
                                 dense, ffn, init_attention, init_ffn,
                                 init_mla, init_moe, mla_attention,
                                 mla_decode, moe_ffn, rms_norm)

Params = Dict[str, Any]
Batch = Dict[str, jax.Array]

XENT_CHUNK = 256


# ------------------------------------------------------------------ utilities
def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _stack_init(init_one: Callable[[jax.Array], Params], rng, n: int) -> Params:
    return jax.vmap(init_one)(jax.random.split(rng, n))


def chunked_xent(h: jax.Array, w_head: jax.Array, targets: jax.Array,
                 mask: Optional[jax.Array] = None,
                 chunk: int = XENT_CHUNK) -> jax.Array:
    """Mean next-token cross-entropy without materialising [B,S,V]."""
    B, S, D = h.shape
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    nc = S // chunk
    h_ = h.reshape(B, nc, chunk, D).transpose(1, 0, 2, 3)
    t_ = targets.reshape(B, nc, chunk).transpose(1, 0, 2)
    if mask is None:
        m_ = jnp.ones((nc, B, chunk), jnp.float32)
    else:
        m_ = mask.reshape(B, nc, chunk).transpose(1, 0, 2).astype(jnp.float32)

    @jax.checkpoint
    def step(acc, xs):
        hc, tc, mc = xs
        logits = jnp.einsum("bsd,dv->bsv", hc, w_head,
                            preferred_element_type=jnp.float32)
        logits = constraint(logits, "batch", "seq", "vocab")
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        loss, cnt = acc
        return (loss + ((lse - tgt) * mc).sum(), cnt + mc.sum()), None

    (loss, cnt), _ = lax.scan(step, (jnp.float32(0), jnp.float32(0)),
                              (h_, t_, m_))
    return loss / jnp.maximum(cnt, 1.0)


def logits_last(h_last: jax.Array, w_head: jax.Array) -> jax.Array:
    """h_last [B,D] -> [B,V] fp32."""
    out = jnp.einsum("bd,dv->bv", h_last, w_head,
                     preferred_element_type=jnp.float32)
    return constraint(out, "batch", "vocab")


# ===================================================================== dense
def _init_block(rng, cfg: ModelConfig, dtype) -> Params:
    k = jax.random.split(rng, 2)
    p = {"ln1": jnp.zeros((cfg.d_model,), dtype),
         "ln2": jnp.zeros((cfg.d_model,), dtype)}
    p["attn"] = init_mla(k[0], cfg, dtype) if cfg.mla else \
        init_attention(k[0], cfg, dtype=dtype)
    if cfg.moe:
        p["moe"] = init_moe(k[1], cfg, dtype)
    else:
        p["ffn"] = init_ffn(k[1], cfg.d_model, cfg.d_ff, dtype)
    return p


def _init_dense_block_for(rng, cfg: ModelConfig, d_ff: int, dtype) -> Params:
    k = jax.random.split(rng, 2)
    return {"ln1": jnp.zeros((cfg.d_model,), dtype),
            "ln2": jnp.zeros((cfg.d_model,), dtype),
            "attn": init_mla(k[0], cfg, dtype) if cfg.mla else
            init_attention(k[0], cfg, dtype=dtype),
            "ffn": init_ffn(k[1], cfg.d_model, d_ff, dtype)}


def _block_fwd(p: Params, h: jax.Array, cfg: ModelConfig,
               ) -> Tuple[jax.Array, jax.Array]:
    """Pre-norm transformer block; returns (h, moe_aux)."""
    a = attention(p["attn"], rms_norm(h, p["ln1"], cfg.norm_eps), cfg) \
        if not cfg.mla else \
        mla_attention(p["attn"], rms_norm(h, p["ln1"], cfg.norm_eps), cfg)
    h = h + a
    hn = rms_norm(h, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        f, aux = moe_ffn(p["moe"], hn, cfg)
    else:
        f, aux = ffn(p["ffn"], hn, cfg.hidden_act), jnp.float32(0)
    h = h + f
    return constraint(h, "batch", "seq", "embed"), aux


def _block_prefill(p: Params, h: jax.Array, cfg: ModelConfig):
    """Like _block_fwd but also returns this layer's KV cache entries."""
    hn = rms_norm(h, p["ln1"], cfg.norm_eps)
    if cfg.mla:
        m = cfg.mla
        B, S, _ = h.shape
        ckv = rms_norm(dense(hn, p["attn"]["w_dkv"]), p["attn"]["kv_norm"],
                       cfg.norm_eps)
        from repro.models.layers import apply_rope, rope_angles
        kr = dense(hn, p["attn"]["w_kr"]).reshape(B, S, 1, m.qk_rope_head_dim)
        sin, cos = rope_angles(jnp.arange(S), m.qk_rope_head_dim,
                               cfg.rope_theta)
        kr = apply_rope(kr, sin, cos).reshape(B, S, m.qk_rope_head_dim)
        kv = (ckv, kr)
        a = mla_attention(p["attn"], hn, cfg)
    else:
        B, S, _ = h.shape
        KV, hd = cfg.num_kv_heads, cfg.head_dim
        from repro.models.layers import apply_rope, rope_angles
        k = dense(hn, p["attn"]["wk"], p["attn"].get("bk")).reshape(B, S, KV, hd)
        v = dense(hn, p["attn"]["wv"], p["attn"].get("bv")).reshape(B, S, KV, hd)
        if cfg.qk_norm:
            k = rms_norm(k, p["attn"]["k_norm"], cfg.norm_eps)
        sin, cos = rope_angles(jnp.arange(S), hd, cfg.rope_theta)
        kv = (apply_rope(k, sin, cos), v)
        a = attention(p["attn"], hn, cfg)
    h = h + a
    hn2 = rms_norm(h, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        f, _ = moe_ffn(p["moe"], hn2, cfg)
    else:
        f = ffn(p["ffn"], hn2, cfg.hidden_act)
    return constraint(h + f, "batch", "seq", "embed"), kv


def _block_decode(p: Params, h: jax.Array, cache, pos, cfg: ModelConfig):
    """One decode block.  ``cache`` is read-only; returns the new token's KV
    entries for the caller to write (append-merge decode)."""
    hn = rms_norm(h, p["ln1"], cfg.norm_eps)
    if cfg.mla:
        a, ckv_new, kr_new = mla_decode(p["attn"], hn, cache[0], cache[1],
                                        pos, cfg)
        new_entries = (ckv_new, kr_new)
    else:
        a, k_new, v_new = attention_decode(p["attn"], hn, cache[0], cache[1],
                                           pos, cfg)
        new_entries = (k_new, v_new)
    h = h + a
    hn2 = rms_norm(h, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        f, _ = moe_ffn(p["moe"], hn2, cfg)
    else:
        f = ffn(p["ffn"], hn2, cfg.hidden_act)
    return h + f, new_entries


# ============================================================= model wrapper
@dataclass
class Model:
    cfg: ModelConfig
    init_params: Callable[[jax.Array], Params]
    train_loss: Callable[[Params, Batch], Tuple[jax.Array, Dict[str, jax.Array]]]
    prefill: Callable[[Params, Batch], Tuple[jax.Array, Any]]
    decode: Callable[[Params, Any, Batch], Tuple[jax.Array, Any]]
    cache_spec: Callable[[int, int], Any]
    input_specs: Callable[[ShapeConfig], Dict[str, jax.ShapeDtypeStruct]]


def build_model(cfg: ModelConfig) -> Model:
    if cfg.ssm and cfg.ssm.kind == "rwkv6":
        return _build_rwkv6(cfg)
    if cfg.ssm and cfg.ssm.kind == "mamba2":
        return _build_zamba(cfg)
    if cfg.encoder_decoder:
        return _build_encdec(cfg)
    return _build_decoder_lm(cfg)


# ---------------------------------------------------- decoder-only (+moe/vlm)
def _build_decoder_lm(cfg: ModelConfig) -> Model:
    dt = _dtype(cfg)
    L = cfg.num_layers
    mo = cfg.moe
    n_prefix = mo.first_k_dense if mo else 0
    n_scan = L - n_prefix
    fe = cfg.frontend

    def init_params(rng) -> Params:
        ks = jax.random.split(rng, 6)
        p: Params = {
            "embed": (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model),
                                        jnp.float32) * 0.02).astype(dt),
            "final_norm": jnp.zeros((cfg.d_model,), dt),
            "layers": _stack_init(lambda k: _init_block(k, cfg, dt), ks[1],
                                  n_scan),
        }
        if n_prefix:
            p["prefix_layers"] = [
                _init_dense_block_for(k, cfg, mo.dense_d_ff or cfg.d_ff, dt)
                for k in jax.random.split(ks[2], n_prefix)]
        if not cfg.tie_embeddings:
            p["lm_head"] = (jax.random.normal(
                ks[3], (cfg.d_model, cfg.vocab_size), jnp.float32)
                / math.sqrt(cfg.d_model)).astype(dt)
        if fe:
            p["frontend_proj"] = {
                "w1": (jax.random.normal(ks[4], (fe.embed_dim, cfg.d_model),
                                         jnp.float32)
                       / math.sqrt(fe.embed_dim)).astype(dt),
                "w2": (jax.random.normal(ks[5], (cfg.d_model, cfg.d_model),
                                         jnp.float32)
                       / math.sqrt(cfg.d_model)).astype(dt)}
        return p

    def head(p):
        return p["embed"].T if cfg.tie_embeddings else p["lm_head"]

    def embed_input(p, batch) -> jax.Array:
        h = jnp.take(p["embed"], batch["tokens"], axis=0)
        if fe:
            img = dense(jax.nn.gelu(dense(
                batch["frontend_embeds"].astype(dt),
                p["frontend_proj"]["w1"])), p["frontend_proj"]["w2"])
            h = jnp.concatenate([img, h], axis=1)
        return constraint(h, "batch", "seq", "embed")

    def backbone(p, h):
        aux = jnp.float32(0)
        for lp in p.get("prefix_layers", []):
            h, a = _block_fwd(lp, h, cfg)
            aux += a
        body = lambda hh, lp: _block_fwd(lp, hh, cfg)
        if cfg.remat == "block":
            body = jax.checkpoint(body)
        def f(hh, lp):
            hh, a = body(hh, lp)
            return hh, a
        h, auxs = lax.scan(f, h, p["layers"])
        return rms_norm(h, p["final_norm"], cfg.norm_eps), aux + auxs.sum()

    def train_loss(p, batch):
        h = embed_input(p, batch)
        h, aux = backbone(p, h)
        if fe:
            n_img = fe.num_tokens
            h = h[:, n_img:, :]
        loss = chunked_xent(h, head(p), batch["targets"],
                            batch.get("loss_mask"))
        total = loss + 0.01 * aux if cfg.moe else loss
        return total, {"xent": loss, "moe_aux": aux}

    def prefill(p, batch):
        h = embed_input(p, batch)
        caches = []
        for lp in p.get("prefix_layers", []):
            h, kv = _block_prefill(lp, h, cfg)
            caches.append(kv)
        def f(hh, lp):
            return _block_prefill(lp, hh, cfg)
        h, kvs = lax.scan(f, h, p["layers"])
        h = rms_norm(h, p["final_norm"], cfg.norm_eps)
        logits = logits_last(h[:, -1, :], head(p))
        cache = {"kv": kvs, "pos": jnp.int32(h.shape[1] - 1)}
        if caches:
            cache["prefix_kv"] = caches
        return logits, cache

    def decode(p, cache, batch):
        h = jnp.take(p["embed"], batch["tokens"], axis=0)
        h = constraint(h, "batch", "seq", "embed")
        pos = batch["pos"]
        new_prefix = []
        for lp, kv in zip(p.get("prefix_layers", []),
                          cache.get("prefix_kv", [])):
            h, (n0, n1) = _block_decode(lp, h, kv, pos, cfg)
            new_prefix.append(
                (lax.dynamic_update_slice_in_dim(kv[0], n0, pos, axis=1),
                 lax.dynamic_update_slice_in_dim(kv[1], n1, pos, axis=1)))

        # append-merge decode: the stacked cache is a READ-ONLY loop
        # invariant (captured, never written in-loop => no per-layer copies);
        # each layer emits its new token's KV and ONE top-level DUS writes
        # all layers at once.
        c0, c1 = cache["kv"]

        def f(hh, xs):
            lp, i = xs
            hh, (n0, n1) = _block_decode(lp, hh, (c0[i], c1[i]), pos, cfg)
            return hh, (n0, n1)

        h, (nk, nv) = lax.scan(f, h, (p["layers"], jnp.arange(n_scan)))
        zero = jnp.zeros((), jnp.int32)
        if cfg.mla:
            idx = (zero, zero, pos, zero)
        else:
            idx = (zero, zero, pos, zero, zero)
        ck = lax.dynamic_update_slice(cache["kv"][0], nk, idx)
        cv = lax.dynamic_update_slice(cache["kv"][1], nv, idx)
        h = rms_norm(h, p["final_norm"], cfg.norm_eps)
        logits = logits_last(h[:, -1, :], head(p))
        new_cache = {"kv": (ck, cv), "pos": pos}
        if new_prefix:
            new_cache["prefix_kv"] = new_prefix
        return logits, new_cache

    def cache_spec(B, T):
        if cfg.mla:
            m = cfg.mla
            kv = (jax.ShapeDtypeStruct((n_scan, B, T, m.kv_lora_rank), dt),
                  jax.ShapeDtypeStruct((n_scan, B, T, m.qk_rope_head_dim), dt))
        else:
            kv = (jax.ShapeDtypeStruct(
                      (n_scan, B, T, cfg.num_kv_heads, cfg.head_dim), dt),
                  jax.ShapeDtypeStruct(
                      (n_scan, B, T, cfg.num_kv_heads, cfg.head_dim), dt))
        spec = {"kv": kv, "pos": jax.ShapeDtypeStruct((), jnp.int32)}
        if n_prefix:
            if cfg.mla:
                m = cfg.mla
                one = (jax.ShapeDtypeStruct((B, T, m.kv_lora_rank), dt),
                       jax.ShapeDtypeStruct((B, T, m.qk_rope_head_dim), dt))
            else:
                one = (jax.ShapeDtypeStruct(
                           (B, T, cfg.num_kv_heads, cfg.head_dim), dt),
                       jax.ShapeDtypeStruct(
                           (B, T, cfg.num_kv_heads, cfg.head_dim), dt))
            spec["prefix_kv"] = [one] * n_prefix
        return spec

    def input_specs(shape: ShapeConfig):
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind == "train":
            n_txt = S - (fe.num_tokens if fe else 0)
            d = {"tokens": jax.ShapeDtypeStruct((B, n_txt), i32),
                 "targets": jax.ShapeDtypeStruct((B, n_txt), i32)}
            if fe:
                d["frontend_embeds"] = jax.ShapeDtypeStruct(
                    (B, fe.num_tokens, fe.embed_dim), dt)
            return d
        if shape.kind == "prefill":
            n_txt = S - (fe.num_tokens if fe else 0)
            d = {"tokens": jax.ShapeDtypeStruct((B, n_txt), i32)}
            if fe:
                d["frontend_embeds"] = jax.ShapeDtypeStruct(
                    (B, fe.num_tokens, fe.embed_dim), dt)
            return d
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32),
                "pos": jax.ShapeDtypeStruct((), i32)}

    return Model(cfg, init_params, train_loss, prefill, decode, cache_spec,
                 input_specs)


# ------------------------------------------------------------ zamba2 (hybrid)
def _build_zamba(cfg: ModelConfig) -> Model:
    dt = _dtype(cfg)
    L = cfg.num_layers
    every = cfg.hybrid_attn_every
    n_inv = (L + every - 1) // every if every else 0
    d_in, H, P, N, conv_dim = ssm_mod.mamba2_dims(cfg)
    K = cfg.ssm.conv_kernel
    shared_cfg = cfg.replace(num_heads=cfg.hybrid_attn_heads or cfg.num_heads)

    def init_shared(rng) -> Params:
        k = jax.random.split(rng, 2)
        return {
            "ln1": jnp.zeros((2 * cfg.d_model,), dt),
            "ln2": jnp.zeros((cfg.d_model,), dt),
            "attn": init_attention(k[0], shared_cfg, d_in=2 * cfg.d_model,
                                   dtype=dt),
            "ffn": init_ffn(k[1], cfg.d_model, cfg.d_ff, dt),
        }

    def init_params(rng) -> Params:
        ks = jax.random.split(rng, 5)
        return {
            "embed": (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model),
                                        jnp.float32) * 0.02).astype(dt),
            "layers": _stack_init(lambda k: init_mamba2_layer(k), ks[1], L),
            "shared": init_shared(ks[2]),
            "final_norm": jnp.zeros((cfg.d_model,), dt),
            "lm_head": (jax.random.normal(ks[3],
                                          (cfg.d_model, cfg.vocab_size),
                                          jnp.float32)
                        / math.sqrt(cfg.d_model)).astype(dt),
        }

    def init_mamba2_layer(rng) -> Params:
        k = jax.random.split(rng, 2)
        return {"ln": jnp.zeros((cfg.d_model,), dt),
                "mamba": ssm_mod.init_mamba2(k[0], cfg, dt)}

    def shared_block(sp, h, x0):
        z = jnp.concatenate([h, x0], axis=-1)
        a = attention(sp["attn"], rms_norm(z, sp["ln1"], cfg.norm_eps),
                      shared_cfg, heads=shared_cfg.num_heads)
        h = bf16_grad(h + a)
        f = ffn(sp["ffn"], rms_norm(h, sp["ln2"], cfg.norm_eps),
                cfg.hidden_act)
        return bf16_grad(h + f)

    def shared_block_decode(sp, h, x0, kv, pos):
        z = jnp.concatenate([h, x0], axis=-1)
        a, k_new, v_new = attention_decode(
            sp["attn"], rms_norm(z, sp["ln1"], cfg.norm_eps), kv[0], kv[1],
            pos, shared_cfg, heads=shared_cfg.num_heads)
        h = h + a
        f = ffn(sp["ffn"], rms_norm(h, sp["ln2"], cfg.norm_eps),
                cfg.hidden_act)
        new_kv = (lax.dynamic_update_slice_in_dim(kv[0], k_new, pos, axis=1),
                  lax.dynamic_update_slice_in_dim(kv[1], v_new, pos, axis=1))
        return h + f, new_kv

    def _seg(p, i0, i1):
        return jax.tree.map(lambda a: a[i0:i1], p["layers"])

    def backbone(p, h):
        x0 = h

        def mamba_body(hh, lp):
            y = ssm_mod.mamba2_block(
                lp["mamba"], rms_norm(hh, lp["ln"], cfg.norm_eps), cfg)
            return constraint(bf16_grad(hh + y), "batch", "seq", "embed"), \
                None

        if cfg.remat == "block":
            mamba_body = jax.checkpoint(mamba_body)
        i = 0
        while i < L:
            if every and i % every == 0:
                h = shared_block(p["shared"], h, x0)
            j = min(L, i + (every or L))
            h, _ = lax.scan(mamba_body, h, _seg(p, i, j))
            i = j
        return rms_norm(h, p["final_norm"], cfg.norm_eps)

    def train_loss(p, batch):
        h = jnp.take(p["embed"], batch["tokens"], axis=0)
        h = constraint(h, "batch", "seq", "embed")
        h = backbone(p, h)
        loss = chunked_xent(h, p["lm_head"], batch["targets"],
                            batch.get("loss_mask"))
        return loss, {"xent": loss}

    def prefill(p, batch):
        h = jnp.take(p["embed"], batch["tokens"], axis=0)
        x0 = h
        B, S, _ = h.shape
        convs, ssds, shared_kv = [], [], []

        def mamba_body(hh, lp):
            y, st, ct = ssm_mod.mamba2_block_with_state(
                lp["mamba"], rms_norm(hh, lp["ln"], cfg.norm_eps), cfg)
            return hh + y, (st, ct)

        i = 0
        while i < L:
            if every and i % every == 0:
                hn = rms_norm(jnp.concatenate([h, x0], -1),
                              p["shared"]["ln1"], cfg.norm_eps)
                KVh, hd = cfg.num_kv_heads, cfg.head_dim
                from repro.models.layers import apply_rope, rope_angles
                k = dense(hn, p["shared"]["attn"]["wk"]).reshape(B, S, KVh, hd)
                v = dense(hn, p["shared"]["attn"]["wv"]).reshape(B, S, KVh, hd)
                sin, cos = rope_angles(jnp.arange(S), hd, cfg.rope_theta)
                shared_kv.append((apply_rope(k, sin, cos), v))
                h = shared_block(p["shared"], h, x0)
            j = min(L, i + (every or L))
            h, (st, ct) = lax.scan(mamba_body, h, _seg(p, i, j))
            convs.append(ct)
            ssds.append(st)
            i = j
        h = rms_norm(h, p["final_norm"], cfg.norm_eps)
        logits = logits_last(h[:, -1, :], p["lm_head"])
        cache = {"conv": jnp.concatenate(convs, 0),
                 "ssd": jnp.concatenate(ssds, 0),
                 "shared_kv": shared_kv,
                 "x0_last": x0[:, -1, :],
                 "pos": jnp.int32(S - 1)}
        return logits, cache

    def decode(p, cache, batch):
        h = jnp.take(p["embed"], batch["tokens"], axis=0)
        x0 = h
        pos = batch["pos"]

        def mamba_body(hh, xs):
            lp, conv, ssd = xs
            y, conv2, ssd2 = ssm_mod.mamba2_decode(
                lp["mamba"], rms_norm(hh, lp["ln"], cfg.norm_eps), conv, ssd,
                cfg)
            return hh + y, (conv2, ssd2)

        new_conv, new_ssd, new_shared = [], [], []
        i, seg = 0, 0
        while i < L:
            if every and i % every == 0:
                h2, kv2 = shared_block_decode(
                    p["shared"], h, x0, cache["shared_kv"][len(new_shared)],
                    pos)
                h = h2
                new_shared.append(kv2)
            j = min(L, i + (every or L))
            n = j - i
            conv_seg = lax.dynamic_slice_in_dim(cache["conv"], i, n, 0)
            ssd_seg = lax.dynamic_slice_in_dim(cache["ssd"], i, n, 0)
            h, (c2, s2) = lax.scan(mamba_body, h,
                                   (_seg(p, i, j), conv_seg, ssd_seg))
            new_conv.append(c2)
            new_ssd.append(s2)
            i = j
            seg += 1
        h = rms_norm(h, p["final_norm"], cfg.norm_eps)
        logits = logits_last(h[:, -1, :], p["lm_head"])
        cache = {"conv": jnp.concatenate(new_conv, 0),
                 "ssd": jnp.concatenate(new_ssd, 0),
                 "shared_kv": new_shared,
                 "x0_last": x0[:, -1, :],
                 "pos": pos}
        return logits, cache

    def cache_spec(B, T):
        KVh, hd = cfg.num_kv_heads, cfg.head_dim
        one_kv = (jax.ShapeDtypeStruct((B, T, KVh, hd), dt),
                  jax.ShapeDtypeStruct((B, T, KVh, hd), dt))
        return {"conv": jax.ShapeDtypeStruct((L, B, K - 1, conv_dim), dt),
                "ssd": jax.ShapeDtypeStruct((L, B, H, N, P), jnp.float32),
                "shared_kv": [one_kv] * n_inv,
                "x0_last": jax.ShapeDtypeStruct((B, cfg.d_model), dt),
                "pos": jax.ShapeDtypeStruct((), jnp.int32)}

    def input_specs(shape: ShapeConfig):
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind == "train":
            return {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                    "targets": jax.ShapeDtypeStruct((B, S), i32)}
        if shape.kind == "prefill":
            return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32),
                "pos": jax.ShapeDtypeStruct((), i32)}

    return Model(cfg, init_params, train_loss, prefill, decode, cache_spec,
                 input_specs)


# --------------------------------------------------------------------- rwkv6
def _build_rwkv6(cfg: ModelConfig) -> Model:
    dt = _dtype(cfg)
    L = cfg.num_layers
    H, N = cfg.num_heads, cfg.ssm.head_dim

    def init_layer(rng) -> Params:
        return {"ln1": jnp.zeros((cfg.d_model,), dt),
                "ln2": jnp.zeros((cfg.d_model,), dt),
                "mix": ssm_mod.init_rwkv6(rng, cfg, dt)}

    def init_params(rng) -> Params:
        ks = jax.random.split(rng, 4)
        return {
            "embed": (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model),
                                        jnp.float32) * 0.02).astype(dt),
            "ln0": jnp.zeros((cfg.d_model,), dt),
            "layers": _stack_init(init_layer, ks[1], L),
            "final_norm": jnp.zeros((cfg.d_model,), dt),
            "lm_head": (jax.random.normal(ks[2],
                                          (cfg.d_model, cfg.vocab_size),
                                          jnp.float32)
                        / math.sqrt(cfg.d_model)).astype(dt),
        }

    def layer_fwd(lp, h, s_att, s_wkv, s_chan):
        a, s_att2, s_wkv2 = ssm_mod.rwkv6_time_mix(
            lp["mix"], rms_norm(h, lp["ln1"], cfg.norm_eps), s_att, s_wkv,
            cfg)
        h = h + a
        c, s_chan2 = ssm_mod.rwkv6_channel_mix(
            lp["mix"], rms_norm(h, lp["ln2"], cfg.norm_eps), s_chan)
        h = h + c
        return constraint(h, "batch", "seq", "embed"), s_att2, s_wkv2, s_chan2

    def _zero_states(B):
        return (jnp.zeros((L, B, cfg.d_model), dt),
                jnp.zeros((L, B, H, N, N), jnp.float32),
                jnp.zeros((L, B, cfg.d_model), dt))

    def backbone(p, h, states):
        s_att, s_wkv, s_chan = states

        def body(hh, xs):
            lp, sa, sw, sc = xs
            hh, sa2, sw2, sc2 = layer_fwd(lp, hh, sa, sw, sc)
            return hh, (sa2, sw2, sc2)

        fn = jax.checkpoint(body) if cfg.remat == "block" else body
        h, (sa, sw, sc) = lax.scan(fn, h, (p["layers"], s_att, s_wkv, s_chan))
        return rms_norm(h, p["final_norm"], cfg.norm_eps), (sa, sw, sc)

    def train_loss(p, batch):
        h = jnp.take(p["embed"], batch["tokens"], axis=0)
        h = rms_norm(h, p["ln0"], cfg.norm_eps)
        h = constraint(h, "batch", "seq", "embed")
        h, _ = backbone(p, h, _zero_states(h.shape[0]))
        loss = chunked_xent(h, p["lm_head"], batch["targets"],
                            batch.get("loss_mask"))
        return loss, {"xent": loss}

    def prefill(p, batch):
        h = jnp.take(p["embed"], batch["tokens"], axis=0)
        h = rms_norm(h, p["ln0"], cfg.norm_eps)
        B = h.shape[0]
        h, (sa, sw, sc) = backbone(p, h, _zero_states(B))
        logits = logits_last(h[:, -1, :], p["lm_head"])
        cache = {"shift_att": sa, "wkv": sw, "shift_chan": sc,
                 "pos": jnp.int32(batch["tokens"].shape[1] - 1)}
        return logits, cache

    def decode(p, cache, batch):
        h = jnp.take(p["embed"], batch["tokens"], axis=0)
        h = rms_norm(h, p["ln0"], cfg.norm_eps)
        h, (sa, sw, sc) = backbone(
            p, h, (cache["shift_att"], cache["wkv"], cache["shift_chan"]))
        logits = logits_last(h[:, -1, :], p["lm_head"])
        return logits, {"shift_att": sa, "wkv": sw, "shift_chan": sc,
                        "pos": cache["pos"] + 1}

    def cache_spec(B, T):
        return {"shift_att": jax.ShapeDtypeStruct((L, B, cfg.d_model), dt),
                "wkv": jax.ShapeDtypeStruct((L, B, H, N, N), jnp.float32),
                "shift_chan": jax.ShapeDtypeStruct((L, B, cfg.d_model), dt),
                "pos": jax.ShapeDtypeStruct((), jnp.int32)}

    def input_specs(shape: ShapeConfig):
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind == "train":
            return {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                    "targets": jax.ShapeDtypeStruct((B, S), i32)}
        if shape.kind == "prefill":
            return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32),
                "pos": jax.ShapeDtypeStruct((), i32)}

    return Model(cfg, init_params, train_loss, prefill, decode, cache_spec,
                 input_specs)


# ----------------------------------------------------------- encoder-decoder
def _build_encdec(cfg: ModelConfig) -> Model:
    dt = _dtype(cfg)
    Ld, Le = cfg.num_layers, cfg.num_encoder_layers
    fe = cfg.frontend

    def init_enc_layer(rng) -> Params:
        k = jax.random.split(rng, 2)
        return {"ln1": jnp.zeros((cfg.d_model,), dt),
                "ln2": jnp.zeros((cfg.d_model,), dt),
                "attn": init_attention(k[0], cfg, dtype=dt),
                "ffn": init_ffn(k[1], cfg.d_model, cfg.d_ff, dt)}

    def init_dec_layer(rng) -> Params:
        k = jax.random.split(rng, 3)
        return {"ln1": jnp.zeros((cfg.d_model,), dt),
                "ln2": jnp.zeros((cfg.d_model,), dt),
                "ln3": jnp.zeros((cfg.d_model,), dt),
                "self_attn": init_attention(k[0], cfg, dtype=dt),
                "cross_attn": init_attention(k[1], cfg, dtype=dt),
                "ffn": init_ffn(k[2], cfg.d_model, cfg.d_ff, dt)}

    def init_params(rng) -> Params:
        ks = jax.random.split(rng, 6)
        return {
            "embed": (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model),
                                        jnp.float32) * 0.02).astype(dt),
            "frontend_proj": (jax.random.normal(
                ks[1], (fe.embed_dim, cfg.d_model), jnp.float32)
                / math.sqrt(fe.embed_dim)).astype(dt),
            "enc_layers": _stack_init(init_enc_layer, ks[2], Le),
            "enc_norm": jnp.zeros((cfg.d_model,), dt),
            "dec_layers": _stack_init(init_dec_layer, ks[3], Ld),
            "final_norm": jnp.zeros((cfg.d_model,), dt),
            "lm_head": (jax.random.normal(ks[4],
                                          (cfg.d_model, cfg.vocab_size),
                                          jnp.float32)
                        / math.sqrt(cfg.d_model)).astype(dt),
        }

    def encode(p, frames):
        h = dense(frames.astype(dt), p["frontend_proj"])
        h = constraint(h, "batch", "seq", "embed")

        def body(hh, lp):
            a = attention(lp["attn"], rms_norm(hh, lp["ln1"], cfg.norm_eps),
                          cfg, causal=False)
            hh = hh + a
            f = ffn(lp["ffn"], rms_norm(hh, lp["ln2"], cfg.norm_eps),
                    cfg.hidden_act)
            return constraint(hh + f, "batch", "seq", "embed"), None

        fn = jax.checkpoint(body) if cfg.remat == "block" else body
        h, _ = lax.scan(fn, h, p["enc_layers"])
        return rms_norm(h, p["enc_norm"], cfg.norm_eps)

    def dec_block(lp, h, enc_out):
        a = attention(lp["self_attn"], rms_norm(h, lp["ln1"], cfg.norm_eps),
                      cfg, causal=True)
        h = h + a
        x = attention(lp["cross_attn"], rms_norm(h, lp["ln2"], cfg.norm_eps),
                      cfg, causal=False, kv_x=enc_out, use_rope=False)
        h = h + x
        f = ffn(lp["ffn"], rms_norm(h, lp["ln3"], cfg.norm_eps),
                cfg.hidden_act)
        return constraint(h + f, "batch", "seq", "embed")

    def train_loss(p, batch):
        enc_out = encode(p, batch["frames"])
        h = jnp.take(p["embed"], batch["tokens"], axis=0)
        h = constraint(h, "batch", "seq", "embed")

        def body(hh, lp):
            return dec_block(lp, hh, enc_out), None

        fn = jax.checkpoint(body) if cfg.remat == "block" else body
        h, _ = lax.scan(fn, h, p["dec_layers"])
        h = rms_norm(h, p["final_norm"], cfg.norm_eps)
        loss = chunked_xent(h, p["lm_head"], batch["targets"],
                            batch.get("loss_mask"))
        return loss, {"xent": loss}

    def prefill(p, batch):
        from repro.models.layers import apply_rope, rope_angles
        enc_out = encode(p, batch["frames"])
        h = jnp.take(p["embed"], batch["tokens"], axis=0)
        B, S, _ = h.shape
        KV, hd = cfg.num_kv_heads, cfg.head_dim

        def body(hh, lp):
            hn = rms_norm(hh, lp["ln1"], cfg.norm_eps)
            k = dense(hn, lp["self_attn"]["wk"]).reshape(B, S, KV, hd)
            v = dense(hn, lp["self_attn"]["wv"]).reshape(B, S, KV, hd)
            sin, cos = rope_angles(jnp.arange(S), hd, cfg.rope_theta)
            k = apply_rope(k, sin, cos)
            xk = dense(enc_out, lp["cross_attn"]["wk"]).reshape(
                B, enc_out.shape[1], KV, hd)
            xv = dense(enc_out, lp["cross_attn"]["wv"]).reshape(
                B, enc_out.shape[1], KV, hd)
            return dec_block(lp, hh, enc_out), (k, v, xk, xv)

        h, (ks_, vs_, xks, xvs) = lax.scan(body, h, p["dec_layers"])
        h = rms_norm(h, p["final_norm"], cfg.norm_eps)
        logits = logits_last(h[:, -1, :], p["lm_head"])
        cache = {"k": ks_, "v": vs_, "xk": xks, "xv": xvs,
                 "pos": jnp.int32(S - 1)}
        return logits, cache

    def decode(p, cache, batch):
        h = jnp.take(p["embed"], batch["tokens"], axis=0)
        pos = batch["pos"]

        def body(hh, xs):
            lp, ck, cv, xk, xv = xs
            a, k_new, v_new = attention_decode(
                lp["self_attn"], rms_norm(hh, lp["ln1"], cfg.norm_eps),
                ck, cv, pos, cfg)
            hh = hh + a
            # cross attention against the precomputed encoder bank
            from repro.models.layers import decode_attention as dec_attn
            hn = rms_norm(hh, lp["ln2"], cfg.norm_eps)
            B = hh.shape[0]
            q = dense(hn, lp["cross_attn"]["wq"]).reshape(
                B, 1, cfg.num_heads, cfg.head_dim)
            o = dec_attn(q, xk, xv, jnp.int32(xk.shape[1] - 1))
            o = o.reshape(B, 1, cfg.num_heads * cfg.head_dim).astype(hh.dtype)
            hh = hh + dense(o, lp["cross_attn"]["wo"])
            f = ffn(lp["ffn"], rms_norm(hh, lp["ln3"], cfg.norm_eps),
                    cfg.hidden_act)
            return hh + f, (k_new, v_new)

        h, (nk1, nv1) = lax.scan(
            body, h, (p["dec_layers"], cache["k"], cache["v"], cache["xk"],
                      cache["xv"]))
        zero = jnp.zeros((), jnp.int32)
        idx = (zero, zero, pos, zero, zero)
        nk = lax.dynamic_update_slice(cache["k"], nk1, idx)
        nv = lax.dynamic_update_slice(cache["v"], nv1, idx)
        h = rms_norm(h, p["final_norm"], cfg.norm_eps)
        logits = logits_last(h[:, -1, :], p["lm_head"])
        return logits, {"k": nk, "v": nv, "xk": cache["xk"],
                        "xv": cache["xv"], "pos": pos}

    def cache_spec(B, T):
        KV, hd = cfg.num_kv_heads, cfg.head_dim
        arr = lambda: jax.ShapeDtypeStruct((Ld, B, T, KV, hd), dt)
        return {"k": arr(), "v": arr(), "xk": arr(), "xv": arr(),
                "pos": jax.ShapeDtypeStruct((), jnp.int32)}

    def input_specs(shape: ShapeConfig):
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind == "train":
            return {"frames": jax.ShapeDtypeStruct((B, S, fe.embed_dim), dt),
                    "tokens": jax.ShapeDtypeStruct((B, S), i32),
                    "targets": jax.ShapeDtypeStruct((B, S), i32)}
        if shape.kind == "prefill":
            return {"frames": jax.ShapeDtypeStruct((B, S, fe.embed_dim), dt),
                    "tokens": jax.ShapeDtypeStruct((B, S), i32)}
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32),
                "pos": jax.ShapeDtypeStruct((), i32)}

    return Model(cfg, init_params, train_loss, prefill, decode, cache_spec,
                 input_specs)
