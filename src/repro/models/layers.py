"""Pure-JAX model layers shared by every assigned architecture.

Conventions
-----------
* Params are nested dicts of jnp arrays; layer stacks carry a leading ``L``
  dim and are consumed with ``jax.lax.scan``.
* Activations default to bf16, softmax/recurrence accumulation in fp32.
* Attention is blocked (flash-style online softmax) so 32k prefill never
  materialises an [S, S] score matrix.  ``attn_impl='masked'`` computes the
  full rectangle with a causal mask (baseline); ``'balanced'`` skips fully
  masked KV blocks (hillclimbed variant, see EXPERIMENTS.md §Perf).
* ``constraint`` calls map logical axes to mesh axes (no-op without a mesh).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig
from repro.launch.sharding import constraint

Params = Dict[str, Any]
NEG_INF = -1e30


@jax.custom_vjp
def bf16_grad(x: jax.Array) -> jax.Array:
    """Identity with a bf16 gradient boundary: cotangents crossing this
    point are cast to bf16, halving the volume of every activation-gradient
    all-reduce upstream (Megatron-style bf16 reductions; hillclimb)."""
    return x


def _bf16_grad_fwd(x):
    return x, None


def _bf16_grad_bwd(_, g):
    return (g.astype(jnp.bfloat16),)


bf16_grad.defvjp(_bf16_grad_fwd, _bf16_grad_bwd)


# --------------------------------------------------------------------- basics
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": partial(jax.nn.gelu, approximate=True),
            "relu": jax.nn.relu}[name]


def dense(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None,
          out_dtype=None) -> jax.Array:
    y = jnp.einsum("...d,df->...f", x, w,
                   preferred_element_type=out_dtype or x.dtype)
    if b is not None:
        y = y + b
    return y.astype(out_dtype or x.dtype)


# ----------------------------------------------------------------------- RoPE
def rope_angles(positions: jax.Array, dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions [*] -> (sin, cos) each [*, dim/2] fp32."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x [..., S, H, D]; sin/cos [S, D/2] (or broadcastable)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    s = sin[..., :, None, :]
    c = cos[..., :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(dt)


# ------------------------------------------------------------------ attention
def blocked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool, q_block: int, kv_block: int,
                      impl: str = "masked",
                      q_offset: int = 0) -> jax.Array:
    """Flash-style blocked attention.

    q [B,S,H,dk]; k [B,T,KV,dk]; v [B,T,KV,dv]; H = KV*G.  Returns [B,S,H,dv].
    ``q_offset``: absolute position of q[0] (for causal masks when S != T).
    ``impl='balanced'`` runs the inner KV scan only over blocks that intersect
    the causal triangle of each query block (exact FLOP reduction; requires
    q_offset such that query block i sees kv up to offset+i*q_block+...).
    """
    B, S, H, dk = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    dv = v.shape[-1]
    q_block = min(q_block, S)
    kv_block = min(kv_block, T)
    S0, T0 = S, T
    pad_s, pad_t = (-S) % q_block, (-T) % kv_block
    if pad_s:
        q = jnp.pad(q, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        S += pad_s
    if pad_t:
        k = jnp.pad(k, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
        T += pad_t
    kv_len = T0 if pad_t else None                         # mask padded kv
    nq, nk = S // q_block, T // kv_block
    scale = 1.0 / math.sqrt(dk)

    qb = q.reshape(B, nq, q_block, KV, G, dk).transpose(1, 0, 2, 3, 4, 5)
    kb = k.reshape(B, nk, kv_block, KV, dk).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nk, kv_block, KV, dv).transpose(1, 0, 2, 3, 4)

    def kv_scan(qc, q_index, k_blocks, v_blocks, k_index0):
        """Online-softmax scan of ``qc`` [B,qb,KV,G,dk] over the given kv
        blocks.  q_index scalar (traced or static); k_index0 static."""
        n = k_blocks.shape[0]
        m0 = jnp.full((B, KV, G, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_block), jnp.float32)
        o0 = jnp.zeros((B, KV, G, q_block, dv), jnp.float32)

        def kv_step(carry, kv):
            m, l, o = carry
            kc, vc, ki = kv
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qc, kc,
                           preferred_element_type=jnp.float32) * scale
            kpos = ki * kv_block + jnp.arange(kv_block)
            if causal:
                qpos = q_offset + q_index * q_block + jnp.arange(q_block)
                mask = qpos[:, None] >= kpos[None, :]
                if kv_len is not None:
                    mask &= (kpos < kv_len)[None, :]
                s = jnp.where(mask[None, None, None], s, NEG_INF)
            elif kv_len is not None:
                s = jnp.where((kpos < kv_len)[None, None, None, None, :],
                              s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vc.dtype), vc,
                            preferred_element_type=jnp.float32)
            o_new = o * alpha[..., None] + pv
            return (m_new, l_new, o_new), None

        (m, l, o), _ = lax.scan(
            kv_step, (m0, l0, o0),
            (k_blocks, v_blocks, k_index0 + jnp.arange(n)))
        return o / jnp.maximum(l, 1e-30)[..., None]         # [B,KV,G,qb,dv]

    if impl == "balanced" and causal and nq > 1:
        # Static unroll over q blocks; block i only scans kv blocks that
        # intersect its causal triangle => HLO FLOPs ~ exact causal cost.
        outs = []
        for i in range(nq):
            hi = min(nk, (q_offset + (i + 1) * q_block + kv_block - 1)
                     // kv_block)
            hi = max(hi, 1)
            outs.append(kv_scan(qb[i], i, kb[:hi], vb[:hi], 0))
        out = jnp.stack(outs, axis=0)
    else:
        out = lax.map(lambda a: kv_scan(a[0], a[1], kb, vb, 0),
                      (qb, jnp.arange(nq)))                 # [nq,B,KV,G,qb,dv]

    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, H, dv)
    return out[:, :S0] if pad_s else out


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array,
                     k_new: Optional[jax.Array] = None,
                     v_new: Optional[jax.Array] = None) -> jax.Array:
    """Single-token attention.  q [B,1,H,dk]; caches [B,T,KV,d*].

    Append-merge form: the cache is READ-ONLY (positions < pos, or <= pos
    when k_new is None) and the new token's (k_new, v_new) [B,1,KV,d*] is
    merged via online softmax.  Keeping the multi-GiB cache read-only inside
    the layer scan lets XLA alias it instead of copying it every layer; the
    caller writes all layers' new KV with one top-level DUS."""
    B, _, H, dk = q.shape
    T, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    dv = v_cache.shape[-1]
    scale = 1.0 / math.sqrt(dk)
    qh = q.reshape(B, KV, G, dk)
    s = jnp.einsum("bhgd,bkhd->bhgk", qh, k_cache,
                   preferred_element_type=jnp.float32) * scale
    limit = pos if k_new is not None else pos + 1
    valid = (jnp.arange(T) < limit)[None, None, None, :]
    s = jnp.where(valid, s, NEG_INF)
    m = s.max(axis=-1)                                     # [B,KV,G]
    if k_new is not None:
        s_self = jnp.einsum("bhgd,bxhd->bhg", qh, k_new,
                            preferred_element_type=jnp.float32) * scale
        m = jnp.maximum(m, s_self)
    p = jnp.exp(s - m[..., None])
    l = p.sum(axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    if k_new is not None:
        p_self = jnp.exp(s_self - m)                       # [B,KV,G]
        l = l + p_self
        o = o + p_self[..., None] * v_new.reshape(B, KV, 1, dv) \
            .astype(jnp.float32)
    o = o / jnp.maximum(l, 1e-30)[..., None]
    return o.reshape(B, 1, H, dv).astype(q.dtype)


# ------------------------------------------------------------- attention core
def init_attention(rng, cfg: ModelConfig, d_in: Optional[int] = None,
                   heads: Optional[int] = None, dtype=jnp.bfloat16) -> Params:
    D = d_in or cfg.d_model
    H = heads or cfg.num_heads
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    k = jax.random.split(rng, 4)
    def w(key, shape, fan_in):
        return (jax.random.normal(key, shape, jnp.float32)
                / math.sqrt(fan_in)).astype(dtype)
    p: Params = {
        "wq": w(k[0], (D, H * hd), D),
        "wk": w(k[1], (D, KV * hd), D),
        "wv": w(k[2], (D, KV * hd), D),
        "wo": w(k[3], (H * hd, cfg.d_model), H * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KV * hd,), dtype)
        p["bv"] = jnp.zeros((KV * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def attention(p: Params, x: jax.Array, cfg: ModelConfig, *,
              heads: Optional[int] = None, causal: bool = True,
              kv_x: Optional[jax.Array] = None,
              positions: Optional[jax.Array] = None,
              use_rope: bool = True) -> jax.Array:
    """Full-sequence attention (train/prefill).  x [B,S,D]."""
    B, S, _ = x.shape
    H = heads or cfg.num_heads
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    src = kv_x if kv_x is not None else x
    T = src.shape[1]
    q = dense(x, p["wq"], p.get("bq")).reshape(B, S, H, hd)
    k = dense(src, p["wk"], p.get("bk")).reshape(B, T, KV, hd)
    v = dense(src, p["wv"], p.get("bv")).reshape(B, T, KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if use_rope:
        pos_q = positions if positions is not None else jnp.arange(S)
        sin, cos = rope_angles(pos_q, hd, cfg.rope_theta)
        q = apply_rope(q, sin, cos)
        sin_k, cos_k = rope_angles(jnp.arange(T), hd, cfg.rope_theta)
        k = apply_rope(k, sin_k, cos_k)
    q = constraint(q, "batch", "seq", "heads", None)
    k = constraint(k, "batch", "seq", "kv_heads", None)
    v = constraint(v, "batch", "seq", "kv_heads", None)
    o = blocked_attention(q, k, v, causal=causal, q_block=cfg.attn_q_block,
                          kv_block=cfg.attn_kv_block, impl=cfg.attn_impl)
    o = o.astype(x.dtype).reshape(B, S, H * hd)
    return dense(o, p["wo"])


def attention_decode(p: Params, x: jax.Array, cache_k: jax.Array,
                     cache_v: jax.Array, pos: jax.Array, cfg: ModelConfig, *,
                     heads: Optional[int] = None, use_rope: bool = True
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token step.  x [B,1,D]; caches [B,T,KV,hd] (read-only; the new
    token occupies logical slot ``pos``).  Returns (out, k_new, v_new) —
    the caller writes (k_new, v_new) into its cache at ``pos``."""
    B = x.shape[0]
    H = heads or cfg.num_heads
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    q = dense(x, p["wq"], p.get("bq")).reshape(B, 1, H, hd)
    k = dense(x, p["wk"], p.get("bk")).reshape(B, 1, KV, hd)
    v = dense(x, p["wv"], p.get("bv")).reshape(B, 1, KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if use_rope:
        sin, cos = rope_angles(pos[None], hd, cfg.rope_theta)
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)
    k = k.astype(cache_k.dtype)
    v = v.astype(cache_v.dtype)
    o = decode_attention(q, cache_k, cache_v, pos, k_new=k, v_new=v)
    o = o.reshape(B, 1, H * hd).astype(x.dtype)
    return dense(o, p["wo"]), k, v


# ------------------------------------------------------------------------ MLA
def init_mla(rng, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    m: MLAConfig = cfg.mla
    D, H = cfg.d_model, cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    k = jax.random.split(rng, 5)
    def w(key, shape, fan_in):
        return (jax.random.normal(key, shape, jnp.float32)
                / math.sqrt(fan_in)).astype(dtype)
    return {
        "w_dq": w(k[0], (D, m.q_lora_rank), D),
        "q_norm": jnp.zeros((m.q_lora_rank,), dtype),
        "w_uq": w(k[1], (m.q_lora_rank, H * qk), m.q_lora_rank),
        "w_dkv": w(k[2], (D, m.kv_lora_rank), D),
        "w_kr": w(k[2], (D, m.qk_rope_head_dim), D),
        "kv_norm": jnp.zeros((m.kv_lora_rank,), dtype),
        "w_ukv": w(k[3], (m.kv_lora_rank,
                          H * (m.qk_nope_head_dim + m.v_head_dim)),
                   m.kv_lora_rank),
        "wo": w(k[4], (H * m.v_head_dim, D), H * m.v_head_dim),
    }


def mla_attention(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """MLA prefill/train path (decompressed K/V, blocked attention)."""
    m: MLAConfig = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    nope, rope_d, vd = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    cq = rms_norm(dense(x, p["w_dq"]), p["q_norm"], cfg.norm_eps)
    q = dense(cq, p["w_uq"]).reshape(B, S, H, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    ckv = rms_norm(dense(x, p["w_dkv"]), p["kv_norm"], cfg.norm_eps)
    k_rope = dense(x, p["w_kr"]).reshape(B, S, 1, rope_d)
    sin, cos = rope_angles(jnp.arange(S), rope_d, cfg.rope_theta)
    q_rope = apply_rope(q_rope, sin, cos)
    k_rope = apply_rope(k_rope, sin, cos)
    kv = dense(ckv, p["w_ukv"]).reshape(B, S, H, nope + vd)
    k_nope, v = kv[..., :nope], kv[..., nope:]
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, H, rope_d))],
                        axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    q = constraint(q, "batch", "seq", "heads", None)
    k = constraint(k, "batch", "seq", "heads", None)
    v = constraint(v, "batch", "seq", "heads", None)
    o = blocked_attention(q, k, v, causal=True, q_block=cfg.attn_q_block,
                          kv_block=cfg.attn_kv_block, impl=cfg.attn_impl)
    o = o.astype(x.dtype).reshape(B, S, H * vd)
    return dense(o, p["wo"])


def mla_decode(p: Params, x: jax.Array, cache_ckv: jax.Array,
               cache_kr: jax.Array, pos: jax.Array, cfg: ModelConfig
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Absorbed MLA decode: scores/attention run in the latent (kv_lora)
    space; per-token KV cache is only kv_lora+rope wide (the MLA win).
    Caches are read-only; returns (out, ckv_new [B,1,r], kr_new [B,1,rd])
    for the caller's single top-level cache write."""
    m: MLAConfig = cfg.mla
    B = x.shape[0]
    H = cfg.num_heads
    nope, rope_d, vd = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    r = m.kv_lora_rank
    cq = rms_norm(dense(x, p["w_dq"]), p["q_norm"], cfg.norm_eps)
    q = dense(cq, p["w_uq"]).reshape(B, 1, H, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    sin, cos = rope_angles(pos[None], rope_d, cfg.rope_theta)
    q_rope = apply_rope(q_rope, sin, cos)

    ckv_t = rms_norm(dense(x, p["w_dkv"]), p["kv_norm"], cfg.norm_eps)
    kr_t = dense(x, p["w_kr"]).reshape(B, 1, 1, rope_d)
    kr_t = apply_rope(kr_t, sin, cos).reshape(B, 1, rope_d)
    ckv_t = ckv_t.astype(cache_ckv.dtype)                  # [B,1,r]
    kr_t = kr_t.astype(cache_kr.dtype)

    w_ukv = p["w_ukv"].reshape(r, H, nope + vd)
    w_uk, w_uv = w_ukv[..., :nope], w_ukv[..., nope:]               # [r,H,*]
    # absorb: q_eff[b,h,:] = q_nope[b,h] @ w_uk[:,h,:]^T  -> latent space
    q_eff = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0], w_uk,
                       preferred_element_type=jnp.float32)
    T = cache_ckv.shape[1]
    scale = 1.0 / math.sqrt(nope + rope_d)
    s = jnp.einsum("bhr,btr->bht", q_eff, cache_ckv.astype(jnp.float32))
    s += jnp.einsum("bhd,btd->bht", q_rope[:, 0].astype(jnp.float32),
                    cache_kr.astype(jnp.float32))
    s = s * scale
    valid = (jnp.arange(T) < pos)[None, None, :]
    s = jnp.where(valid, s, NEG_INF)
    s_self = (jnp.einsum("bhr,bxr->bh", q_eff, ckv_t.astype(jnp.float32))
              + jnp.einsum("bhd,bxd->bh", q_rope[:, 0].astype(jnp.float32),
                           kr_t.astype(jnp.float32))) * scale
    mx = jnp.maximum(s.max(axis=-1), s_self)
    pattn = jnp.exp(s - mx[..., None])
    p_self = jnp.exp(s_self - mx)
    l = pattn.sum(axis=-1) + p_self
    ctx = jnp.einsum("bht,btr->bhr", pattn, cache_ckv.astype(jnp.float32))
    ctx = ctx + p_self[..., None] * ckv_t.astype(jnp.float32)
    ctx = ctx / jnp.maximum(l, 1e-30)[..., None]
    o = jnp.einsum("bhr,rhv->bhv", ctx, w_uv.astype(jnp.float32))
    o = o.reshape(B, 1, H * vd).astype(x.dtype)
    return dense(o, p["wo"]), ckv_t, kr_t


# ------------------------------------------------------------------------ FFN
def init_ffn(rng, d_model: int, d_ff: int, dtype=jnp.bfloat16) -> Params:
    k = jax.random.split(rng, 3)
    def w(key, shape, fan_in):
        return (jax.random.normal(key, shape, jnp.float32)
                / math.sqrt(fan_in)).astype(dtype)
    return {"w_gate": w(k[0], (d_model, d_ff), d_model),
            "w_up": w(k[1], (d_model, d_ff), d_model),
            "w_down": w(k[2], (d_ff, d_model), d_ff)}


def ffn(p: Params, x: jax.Array, act: str) -> jax.Array:
    g = dense(x, p["w_gate"])
    u = dense(x, p["w_up"])
    h = act_fn(act)(g.astype(jnp.float32)).astype(x.dtype) * u
    h = constraint(h, "batch", "seq", "mlp")
    return dense(h, p["w_down"])


# ------------------------------------------------------------------------ MoE
def init_moe(rng, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    mo: MoEConfig = cfg.moe
    D, E, F = cfg.d_model, mo.num_experts, mo.d_ff
    k = jax.random.split(rng, 5)
    def w(key, shape, fan_in):
        return (jax.random.normal(key, shape, jnp.float32)
                / math.sqrt(fan_in)).astype(dtype)
    p: Params = {
        "router": w(k[0], (D, E), D).astype(jnp.float32),
        "w_gate": w(k[1], (E, D, F), D),
        "w_up": w(k[2], (E, D, F), D),
        "w_down": w(k[3], (E, F, D), F),
    }
    if mo.num_shared_experts:
        p["shared"] = init_ffn(
            k[4], D, mo.num_shared_experts * (mo.shared_d_ff or F), dtype)
    return p


def moe_ffn(p: Params, x: jax.Array, cfg: ModelConfig
            ) -> Tuple[jax.Array, jax.Array]:
    """Dropless-ish capacity MoE with per-batch-row grouping (GShard style).

    x [B,S,D].  Group = batch row, so dispatch stays local to the data shard
    and GSPMD inserts the expert all-to-all on the [B,E,C,D] buffer.
    Returns (y, aux_loss)."""
    mo: MoEConfig = cfg.moe
    B, S, D = x.shape
    E, K = mo.num_experts, mo.num_experts_per_tok
    C = max(1, int(math.ceil(K * S / E * mo.capacity_factor)))
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                 # [B,S,E] fp32
    gates, idx = lax.top_k(probs, K)                        # [B,S,K]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)        # [B,S,K,E]
    flat = onehot.reshape(B, S * K, E)
    pos_all = jnp.cumsum(flat, axis=1) - 1                  # position in expert
    pos = (pos_all.reshape(B, S, K, E) * onehot).sum(-1)    # [B,S,K]
    keep = pos < C
    gates = jnp.where(keep, gates, 0.0)

    # dispatch: scatter tokens into [B,E,C,D].  The scatter runs on a
    # batch-sharded/expert-replicated layout (local, no collective); the
    # constraint to expert-sharded afterwards is a local slice.  Gathering
    # straight out of an expert-sharded buffer would instead make GSPMD emit
    # a full [B,S,K,D] fp32 all-reduce per layer (measured 8 GB x944 on
    # deepseek-v2 train before this layout).
    pos_c = jnp.clip(pos, 0, C - 1)
    xk = jnp.where(keep[..., None],
                   jnp.broadcast_to(x[:, :, None, :], (B, S, K, D)), 0)

    def row_scatter(ix, ps, vals):
        # [S,K]->[E,C,D]: per-batch-row scatter keeps the batch dim a real
        # scatter batching dim, so GSPMD keeps it sharded (flattened fancy
        # indexing replicates the batch and all-reduces [B,S,K,D] instead).
        return jnp.zeros((E, C, D), x.dtype).at[ix, ps].add(vals)

    buf = jax.vmap(row_scatter)(idx, pos_c, xk)
    if cfg.expert_scheme == "ep_data_tp_ffn":
        # tokens move to the expert's data-shard (a2a); expert FFN hidden is
        # model-sharded, so the weights never move (serving hillclimb)
        buf = constraint(buf, None, "experts_data", None, None)
        g = jnp.einsum("becd,edf->becf", buf, p["w_gate"],
                       preferred_element_type=jnp.bfloat16).astype(x.dtype)
        u = jnp.einsum("becd,edf->becf", buf, p["w_up"],
                       preferred_element_type=jnp.bfloat16).astype(x.dtype)
        h = act_fn(cfg.hidden_act)(g.astype(jnp.float32)).astype(x.dtype) * u
        h = constraint(h, None, "experts_data", None, "mlp")
        y_buf = jnp.einsum("becf,efd->becd", h, p["w_down"],
                           preferred_element_type=jnp.bfloat16).astype(x.dtype)
    else:
        buf = constraint(buf, "batch", "experts", None, None)
        g = jnp.einsum("becd,edf->becf", buf, p["w_gate"],
                       preferred_element_type=jnp.bfloat16).astype(x.dtype)
        u = jnp.einsum("becd,edf->becf", buf, p["w_up"],
                       preferred_element_type=jnp.bfloat16).astype(x.dtype)
        h = act_fn(cfg.hidden_act)(g.astype(jnp.float32)).astype(x.dtype) * u
        h = constraint(h, "batch", "experts", None, None)
        y_buf = jnp.einsum("becf,efd->becd", h, p["w_down"],
                           preferred_element_type=jnp.bfloat16).astype(x.dtype)
    # back to batch-only sharding (all-gather over the model axis of the
    # small bf16 buffer — the EP "return" a2a) so the combine gather is local
    y_buf = constraint(y_buf, "batch", None, None, None)

    # combine: gather each token's K expert outputs (batched gather)
    y = jax.vmap(lambda yb, ix, ps: yb[ix, ps])(y_buf, idx, pos_c)
    y = (y.astype(jnp.float32)
         * gates[..., None]).sum(axis=2).astype(x.dtype)

    if "shared" in p:
        y = y + ffn(p["shared"], x, cfg.hidden_act)

    # Switch-style load-balance aux loss
    me = probs.mean(axis=(0, 1))                            # [E]
    ce = (onehot.sum(2).reshape(B * S, E) > 0).astype(jnp.float32).mean(0)
    aux = (me * ce).sum() * E
    return y, aux
