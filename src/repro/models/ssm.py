"""Mamba2 (SSD, chunked) and RWKV6 (Finch, data-dependent decay) blocks.

Both are written so train/prefill use chunk-parallel / precomputed-projection
forms (MXU-friendly) and decode is an O(1)-per-token state update — the
property that makes these the only archs running the ``long_500k`` shape.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, SSMConfig
from repro.launch.sharding import constraint
from repro.models.layers import bf16_grad, dense, rms_norm

Params = Dict[str, Any]


# ------------------------------------------------------------------- mamba2
def mamba2_dims(cfg: ModelConfig) -> Tuple[int, int, int, int, int]:
    s: SSMConfig = cfg.ssm
    d_in = s.expand * cfg.d_model
    heads = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * s.state_dim
    return d_in, heads, s.head_dim, s.state_dim, conv_dim


def init_mamba2(rng, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    """Projections are stored per-segment (z/x/B/C/dt and per-stream convs)
    rather than as Mamba2's fused in_proj: mathematically identical, but each
    matrix column-shards cleanly on the model axis (DESIGN.md §8)."""
    s: SSMConfig = cfg.ssm
    D = cfg.d_model
    d_in, H, P, N, conv_dim = mamba2_dims(cfg)
    gn = s.n_groups * N
    k = jax.random.split(rng, 8)
    def w(key, shape, fan_in):
        return (jax.random.normal(key, shape, jnp.float32)
                / math.sqrt(fan_in)).astype(dtype)
    return {
        "w_z": w(k[0], (D, d_in), D),
        "w_x": w(k[1], (D, d_in), D),
        "w_Bm": w(k[2], (D, gn), D),
        "w_Cm": w(k[3], (D, gn), D),
        "w_dt": w(k[4], (D, H), D),
        "conv_x": w(k[5], (s.conv_kernel, d_in), s.conv_kernel),
        "conv_B": w(k[6], (s.conv_kernel, gn), s.conv_kernel),
        "conv_C": w(k[7], (s.conv_kernel, gn), s.conv_kernel),
        "conv_bx": jnp.zeros((d_in,), dtype),
        "conv_bB": jnp.zeros((gn,), dtype),
        "conv_bC": jnp.zeros((gn,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": jnp.zeros((d_in,), dtype),
        "w_out": w(k[3], (d_in, D), d_in),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv.  x [B,S,C]; w [K,C]."""
    K = w.shape[0]
    y = lax.conv_general_dilated(
        x, w[:, None, :].astype(x.dtype),
        window_strides=(1,), padding=[(K - 1, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1])
    return y + b


def ssd_chunked(xs: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, chunk: int,
                init_state: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan (Mamba2 §6).  xs [B,S,H,P]; dt [B,S,H]; A [H] (<0);
    Bm/Cm [B,S,G,N].  Returns (y [B,S,H,P], final_state [B,H,N,P])."""
    B_, S, H, P = xs.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q

    x_ = xs.reshape(B_, nc, Q, G, rep, P).astype(jnp.float32)
    dt_ = dt.reshape(B_, nc, Q, G, rep).astype(jnp.float32)
    Bm_ = Bm.reshape(B_, nc, Q, G, N).astype(jnp.float32)
    Cm_ = Cm.reshape(B_, nc, Q, G, N).astype(jnp.float32)
    A_ = A.reshape(G, rep)

    dA = dt_ * A_                                          # [B,nc,Q,G,rep] <=0
    cum = jnp.cumsum(dA, axis=2)
    dtx = dt_[..., None] * x_                              # [B,nc,Q,G,rep,P]

    # intra-chunk (quadratic within chunk)
    CB = jnp.einsum("bcign,bcjgn->bcgij", Cm_, Bm_)        # [B,nc,G,Q,Q]
    diff = cum[:, :, :, None] - cum[:, :, None, :, :]      # i,j -> [B,nc,Q,Q,G,rep]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    ldec = jnp.where(tri[None, None, :, :, None, None], diff, -jnp.inf)
    decay = jnp.exp(ldec)                                  # [B,nc,Q,Q,G,rep]
    M = CB.transpose(0, 1, 3, 4, 2)[..., None] * decay     # [B,nc,Q,Q,G,rep]
    y_intra = jnp.einsum("bcijgr,bcjgrp->bcigrp", M, dtx)

    # chunk-local end states
    dec_end = jnp.exp(cum[:, :, -1:, :, :] - cum)          # [B,nc,Q,G,rep]
    S_loc = jnp.einsum("bcjgr,bcjgn,bcjgrp->bcgrnp", dec_end, Bm_, dtx)

    # inter-chunk recurrence
    chunk_dec = jnp.exp(cum[:, :, -1])                     # [B,nc,G,rep]
    if init_state is None:
        s0 = jnp.zeros((B_, G, rep, N, P), jnp.float32)
    else:
        s0 = init_state.reshape(B_, G, rep, N, P).astype(jnp.float32)

    def step(s_prev, inp):
        s_loc, cdec = inp
        return s_prev * cdec[..., None, None] + s_loc, s_prev

    s_final, s_prevs = lax.scan(
        step, s0, (S_loc.transpose(1, 0, 2, 3, 4, 5),
                   chunk_dec.transpose(1, 0, 2, 3)))
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4, 5)          # [B,nc,G,rep,N,P]

    y_inter = jnp.einsum("bcign,bcgrnp->bcigrp", Cm_, s_prevs) \
        * jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(B_, S, H, P)
    return y.astype(xs.dtype), s_final.reshape(B_, H, N, P)


def mamba2_block(p: Params, x: jax.Array, cfg: ModelConfig,
                 ) -> jax.Array:
    """Train/prefill Mamba2 block.  x [B,S,D] -> [B,S,D]."""
    y, _, _ = mamba2_block_with_state(p, x, cfg)
    return y


def mamba2_block_with_state(p: Params, x: jax.Array, cfg: ModelConfig
                            ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    s: SSMConfig = cfg.ssm
    B, S, D = x.shape
    d_in, H, P, N, conv_dim = mamba2_dims(cfg)
    gn = s.n_groups * N
    # bf16 gradient boundary on each TP-sharded projection output: the
    # cotangents feeding these dots' backward all-reduces otherwise arrive
    # in fp32 from the silu/norm internals (2x collective volume)
    z = bf16_grad(dense(x, p["w_z"]))
    x_pre = bf16_grad(dense(x, p["w_x"]))
    B_pre = bf16_grad(dense(x, p["w_Bm"]))
    C_pre = bf16_grad(dense(x, p["w_Cm"]))
    dt = bf16_grad(dense(x, p["w_dt"]))
    conv_tail = jnp.concatenate(
        [x_pre, B_pre, C_pre], axis=-1)[:, -(s.conv_kernel - 1):, :]
    def conv(v, w, b):
        return jax.nn.silu(_causal_conv(v, w, b).astype(jnp.float32)) \
            .astype(x.dtype)
    xs = conv(x_pre, p["conv_x"], p["conv_bx"])
    xs = constraint(xs, "batch", "seq", "ssm_inner").reshape(B, S, H, P)
    Bm = conv(B_pre, p["conv_B"], p["conv_bB"]).reshape(B, S, s.n_groups, N)
    Cm = conv(C_pre, p["conv_C"], p["conv_bC"]).reshape(B, S, s.n_groups, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, state = ssd_chunked(xs, dt, A, Bm, Cm, s.chunk)
    y = y + (p["D_skip"][:, None] * xs.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(B, S, d_in)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["norm"], cfg.norm_eps)
    return dense(y, p["w_out"]), state, conv_tail


def mamba2_decode(p: Params, x: jax.Array, conv_state: jax.Array,
                  ssd_state: jax.Array, cfg: ModelConfig
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token Mamba2 step.  x [B,1,D]; conv_state [B,K-1,conv_dim];
    ssd_state [B,H,N,P]."""
    s: SSMConfig = cfg.ssm
    B = x.shape[0]
    d_in, H, P, N, conv_dim = mamba2_dims(cfg)
    gn = s.n_groups * N
    x0 = x[:, 0]
    z = dense(x0, p["w_z"])
    new_pre = jnp.concatenate([dense(x0, p["w_x"]), dense(x0, p["w_Bm"]),
                               dense(x0, p["w_Cm"])], axis=-1)
    dt = dense(x0, p["w_dt"])

    window = jnp.concatenate([conv_state, new_pre[:, None, :]], axis=1)
    conv_state = window[:, 1:, :]
    conv_w = jnp.concatenate([p["conv_x"], p["conv_B"], p["conv_C"]], axis=-1)
    conv_b = jnp.concatenate([p["conv_bx"], p["conv_bB"], p["conv_bC"]],
                             axis=-1)
    xBC = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                     conv_w.astype(jnp.float32)) + conv_b.astype(jnp.float32)
    xBC = jax.nn.silu(xBC).astype(x.dtype)

    xs = xBC[..., :d_in].reshape(B, H, P).astype(jnp.float32)
    Bm = xBC[..., d_in:d_in + gn].reshape(B, s.n_groups, N)
    Cm = xBC[..., d_in + gn:].reshape(B, s.n_groups, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # [B,H]
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)                                   # [B,H]
    rep = H // s.n_groups
    Bh = jnp.repeat(Bm.astype(jnp.float32), rep, axis=1)   # [B,H,N]
    Ch = jnp.repeat(Cm.astype(jnp.float32), rep, axis=1)
    dBx = dt[..., None, None] * Bh[..., :, None] * xs[..., None, :]
    state = ssd_state.astype(jnp.float32) * dA[..., None, None] + dBx
    y = jnp.einsum("bhn,bhnp->bhp", Ch, state)
    y = y + p["D_skip"][:, None] * xs
    y = y.reshape(B, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["norm"], cfg.norm_eps)
    return dense(y, p["w_out"])[:, None, :], conv_state, state.astype(ssd_state.dtype)


# --------------------------------------------------------------------- rwkv6
LORA_MIX = 32
LORA_DECAY = 64


def init_rwkv6(rng, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    D = cfg.d_model
    H = cfg.num_heads
    N = cfg.ssm.head_dim
    assert H * N == D, (H, N, D)
    k = jax.random.split(rng, 10)
    def w(key, shape, fan_in):
        return (jax.random.normal(key, shape, jnp.float32)
                / math.sqrt(fan_in)).astype(dtype)
    return {
        "mu_base": jnp.zeros((D,), dtype),
        "mu": jnp.zeros((5, D), dtype),                    # r,k,v,w,g lerp
        "lora_A": w(k[0], (D, 5 * LORA_MIX), D),
        "lora_B": w(k[1], (5, LORA_MIX, D), LORA_MIX),
        "w0": jnp.full((D,), -0.6, jnp.float32),           # decay base
        "decay_A": w(k[2], (D, LORA_DECAY), D),
        "decay_B": w(k[3], (LORA_DECAY, D), LORA_DECAY),
        "wr": w(k[4], (D, D), D),
        "wk": w(k[5], (D, D), D),
        "wv": w(k[6], (D, D), D),
        "wg": w(k[7], (D, D), D),
        "u": jnp.zeros((H, N), jnp.float32),               # bonus
        "ln_scale": jnp.ones((D,), jnp.float32),
        "ln_bias": jnp.zeros((D,), jnp.float32),
        "wo": w(k[8], (D, D), D),
        "cm_mu_k": jnp.zeros((D,), dtype),
        "cm_mu_r": jnp.zeros((D,), dtype),
        "cm_wk": w(k[9], (D, cfg.d_ff), D),
        "cm_wv": w(k[0], (cfg.d_ff, D), cfg.d_ff),
        "cm_wr": w(k[1], (D, D), D),
    }


def _group_norm_heads(y: jax.Array, scale: jax.Array, bias: jax.Array,
                      H: int, eps: float = 64e-5) -> jax.Array:
    """GroupNorm with one group per head; y [...,D]."""
    shp = y.shape
    y = y.reshape(shp[:-1] + (H, shp[-1] // H)).astype(jnp.float32)
    mu = y.mean(-1, keepdims=True)
    var = y.var(-1, keepdims=True)
    y = (y - mu) * lax.rsqrt(var + eps)
    y = y.reshape(shp)
    return y * scale + bias


def rwkv6_time_mix(p: Params, x: jax.Array, shift_state: jax.Array,
                   wkv_state: jax.Array, cfg: ModelConfig
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x [B,S,D]; shift_state [B,D] (x_{-1}); wkv_state [B,H,N,N] fp32.
    Returns (out, new_shift, new_wkv)."""
    B, S, D = x.shape
    H = cfg.num_heads
    N = cfg.ssm.head_dim
    x_prev = jnp.concatenate([shift_state[:, None, :].astype(x.dtype),
                              x[:, :-1, :]], axis=1)
    dx = x_prev - x
    xxx = x + dx * p["mu_base"]
    st = jnp.tanh(dense(xxx, p["lora_A"])).reshape(B, S, 5, LORA_MIX)
    adj = jnp.einsum("bsfr,frd->bsfd", st, p["lora_B"])
    mix = x[:, :, None, :] + dx[:, :, None, :] * (p["mu"] + adj)
    xr, xk, xv, xw, xg = [mix[:, :, i, :] for i in range(5)]

    r = dense(xr, p["wr"]).reshape(B, S, H, N).astype(jnp.float32)
    kk = dense(xk, p["wk"]).reshape(B, S, H, N).astype(jnp.float32)
    v = dense(xv, p["wv"]).reshape(B, S, H, N).astype(jnp.float32)
    g = jax.nn.silu(dense(xg, p["wg"]).astype(jnp.float32))
    ww = p["w0"] + dense(jnp.tanh(dense(xw, p["decay_A"])), p["decay_B"]) \
        .astype(jnp.float32)
    w = jnp.exp(-jnp.exp(ww)).reshape(B, S, H, N)          # decay in (0,1)
    u = p["u"]

    chunk = cfg.ssm.chunk if cfg.ssm else 0
    if S > 1 and chunk and S % min(chunk, S) == 0:
        y, new_state = _rwkv6_chunked(r, kk, v, w, u,
                                      wkv_state.astype(jnp.float32),
                                      min(chunk, S))
    else:
        def step(state, inp):
            rt, kt, vt, wt = inp                           # [B,H,N]
            kv = kt[..., :, None] * vt[..., None, :]       # [B,H,N,N]
            yt = jnp.einsum("bhi,bhij->bhj", rt,
                            state + u[..., :, None] * kv)
            state = wt[..., :, None] * state + kv
            return state, yt

        xs = (r.transpose(1, 0, 2, 3), kk.transpose(1, 0, 2, 3),
              v.transpose(1, 0, 2, 3), w.transpose(1, 0, 2, 3))
        new_state, ys = lax.scan(step, wkv_state.astype(jnp.float32), xs)
        y = ys.transpose(1, 0, 2, 3)
    y = y.reshape(B, S, D)                                 # fp32
    y = _group_norm_heads(y, p["ln_scale"], p["ln_bias"], H)
    y = (y * g).astype(x.dtype)
    return dense(y, p["wo"]), x[:, -1, :], new_state.astype(wkv_state.dtype)


def _rwkv6_chunked(r, k, v, w, u, s0, Q: int):
    """Exact chunk-parallel RWKV6 recurrence (hillclimb: the per-step scan
    writes the [B,H,N,N] state to HBM 4096x per layer; chunking cuts the
    sequential depth to S/Q and turns the work into MXU matmuls).

    r/k/v/w [B,S,H,N] fp32; u [H,N]; s0 [B,H,N,N].
    y_t = r_t.(S_{t-1} + diag(u k_t) v_t);  S_t = diag(w_t) S_{t-1} + k_t v_t^T
    Within a chunk, with cl = cumsum(log w):
      y_t = (r_t*exp(cl_{t-1})) . S_0
          + sum_{j<t} (sum_n r_t k_j exp(cl_{t-1}-cl_j))_n v_j
          + (r_t . (u*k_t)) v_t
    """
    B, S, H, N = r.shape
    nc = S // Q
    resh = lambda t: t.reshape(B, nc, Q, H, N).transpose(1, 0, 3, 2, 4)
    rc, kc, vc, wc = resh(r), resh(k), resh(v), resh(w)    # [nc,B,H,Q,N]
    logw = jnp.log(jnp.maximum(wc, 1e-12))
    cl = jnp.cumsum(logw, axis=-2)                         # inclusive
    cl_prev = cl - logw                                    # exclusive

    tri = jnp.tril(jnp.ones((Q, Q), bool), k=-1)           # strict lower

    def chunk_step(S_state, inp):
        rq, kq, vq, clq, clprevq = inp                     # [B,H,Q,N]
        # inter-chunk: r decayed back to chunk start, applied to carry state
        y_inter = jnp.einsum("bhqn,bhnm->bhqm",
                             rq * jnp.exp(clprevq), S_state)
        # intra-chunk pairwise decays (exact, stable: exponent <= 0)
        dd = clprevq[..., :, None, :] - clq[..., None, :, :]  # [B,H,Q,Q,N]
        dd = jnp.where(tri[None, None, :, :, None], dd, -jnp.inf)
        s = jnp.einsum("bhtn,bhjn,bhtjn->bhtj", rq, kq, jnp.exp(dd))
        y_intra = jnp.einsum("bhtj,bhjm->bhtm", s, vq)
        # diagonal bonus term
        y_diag = jnp.einsum("bhqn,bhqn->bhq", rq, kq * u[:, None, :]) \
            [..., None] * vq
        # state to chunk end
        dec_end = jnp.exp(clq[..., -1:, :] - clq)          # [B,H,Q,N]
        S_new = S_state * jnp.exp(clq[..., -1, :])[..., :, None] \
            + jnp.einsum("bhjn,bhjm->bhnm", kq * dec_end, vq)
        return S_new, y_inter + y_intra + y_diag

    s_final, ys = lax.scan(chunk_step, s0, (rc, kc, vc, cl, cl_prev))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, S, H, N)
    return y, s_final


def rwkv6_channel_mix(p: Params, x: jax.Array, shift_state: jax.Array
                      ) -> Tuple[jax.Array, jax.Array]:
    x_prev = jnp.concatenate([shift_state[:, None, :].astype(x.dtype),
                              x[:, :-1, :]], axis=1)
    dx = x_prev - x
    xk = x + dx * p["cm_mu_k"]
    xr = x + dx * p["cm_mu_r"]
    k = jnp.square(jax.nn.relu(dense(xk, p["cm_wk"]).astype(jnp.float32)))
    k = constraint(k.astype(x.dtype), "batch", "seq", "mlp")
    out = jax.nn.sigmoid(dense(xr, p["cm_wr"]).astype(jnp.float32)) \
        .astype(x.dtype) * dense(k, p["cm_wv"])
    return out, x[:, -1, :]
