"""Deterministic, resumable synthetic token pipeline.

Batches are a pure function of (seed, step) — recovery/elastic restart just
sets the step counter (no reader state to persist beyond one integer, which
the checkpoint manifest stores).  The token stream has learnable structure
(a noisy affine bigram process) so smoke training shows decreasing loss.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.1            # fraction of random next-tokens
    frontend_tokens: int = 0      # VLM/audio stub embeddings
    frontend_dim: int = 0
    encoder_decoder: bool = False


def batch_at(cfg: DataConfig, step: int) -> Dict[str, jax.Array]:
    """Batch for one step; identical for identical (cfg, step)."""
    rng = np.random.RandomState((cfg.seed * 1_000_003 + step) % 2 ** 31)
    V = cfg.vocab_size
    a = 31 % V or 1
    c = 17 % V
    B, S = cfg.global_batch, cfg.seq_len
    toks = np.empty((B, S + 1), np.int32)
    toks[:, 0] = rng.randint(0, V, B)
    noise = rng.rand(B, S) < cfg.noise
    rand_next = rng.randint(0, V, (B, S))
    for t in range(S):
        nxt = (toks[:, t] * a + c) % V
        toks[:, t + 1] = np.where(noise[:, t], rand_next[:, t], nxt)
    batch = {"tokens": jnp.asarray(toks[:, :-1]),
             "targets": jnp.asarray(toks[:, 1:])}
    if cfg.frontend_tokens:
        batch["frontend_embeds"] = jnp.asarray(
            rng.randn(B, cfg.frontend_tokens, cfg.frontend_dim)
            .astype(np.float32))
    if cfg.encoder_decoder:
        batch["frames"] = jnp.asarray(
            rng.randn(B, S, cfg.frontend_dim).astype(np.float32))
    return batch


def stream(cfg: DataConfig, start_step: int = 0) -> Iterator[Dict]:
    step = start_step
    while True:
        yield batch_at(cfg, step)
        step += 1
