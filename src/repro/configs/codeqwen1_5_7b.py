"""codeqwen1.5-7b — 32L d_model=4096 32H (kv=32) d_ff=13440 vocab=92416,
qwen1.5-arch (MHA, QKV bias).  [hf:Qwen/CodeQwen1.5-7B; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=13440,
    vocab_size=92416,
    hidden_act="silu",
    qkv_bias=True,
    rope_theta=1000000.0,
    source="hf:Qwen/CodeQwen1.5-7B; hf",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=96, vocab_size=512, attn_q_block=32, attn_kv_block=32)
