"""rwkv6-3b — [ssm] 32L d_model=2560 (attn-free) d_ff=8960 vocab=65536 —
Finch, data-dependent decay.  [arXiv:2404.05892; hf]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,                  # d_model / head_dim(64)
    num_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    hidden_act="relu",             # channel-mix uses squared ReLU
    ssm=SSMConfig(kind="rwkv6", state_dim=64, head_dim=64, chunk=128),
    source="arXiv:2404.05892; hf",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512,
        ssm=SSMConfig(kind="rwkv6", state_dim=16, head_dim=16, chunk=32))
