"""qwen3-moe-30b-a3b — [moe] 48L d_model=2048 32H (GQA kv=4) d_ff=768
vocab=151936, MoE 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.configs.base import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,                      # per-expert width (also used as default)
    vocab_size=151936,
    hidden_act="silu",
    qk_norm=True,
    rope_theta=1000000.0,
    moe=MoEConfig(num_experts=128, num_experts_per_tok=8, d_ff=768,
                  num_shared_experts=0, capacity_factor=1.25),
    source="hf:Qwen/Qwen3-30B-A3B; hf",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=32, vocab_size=512,
        moe=MoEConfig(num_experts=8, num_experts_per_tok=2, d_ff=32,
                      capacity_factor=1.5),
        attn_q_block=32, attn_kv_block=32)
