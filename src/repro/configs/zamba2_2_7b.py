"""zamba2-2.7b — [hybrid] 54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000,
ssm_state=64 — Mamba2 backbone + shared attention blocks.  [arXiv:2411.15242; hf]

Shared transformer block (attention + MLP over concat(hidden, embedding))
applied every 6th layer; per-invocation LoRA deltas of Zamba2 are omitted
(DESIGN.md §8).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    hidden_act="gelu",
    ssm=SSMConfig(kind="mamba2", state_dim=64, head_dim=64, expand=2,
                  conv_kernel=4, n_groups=1, chunk=128),
    hybrid_attn_every=6,
    hybrid_attn_heads=32,
    source="arXiv:2411.15242; hf",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512,
        ssm=SSMConfig(kind="mamba2", state_dim=16, head_dim=16, expand=2,
                      conv_kernel=4, n_groups=1, chunk=32),
        hybrid_attn_every=2, hybrid_attn_heads=4,
        attn_q_block=32, attn_kv_block=32)
