"""deepseek-v2-236b — [moe] 60L d_model=5120 128H d_ff=1536 vocab=102400,
MLA kv_lora=512, 2 shared + 160 routed experts top-6.  [arXiv:2405.04434; hf]

First layer uses a dense FFN (width 12288) per the paper; MLA dims:
q_lora 1536, kv_lora 512, qk_nope 128, qk_rope 64, v_head 128.
"""
from repro.configs.base import MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,              # MLA: all heads share the latent KV
    head_dim=128,
    d_ff=1536,                     # routed-expert width
    vocab_size=102400,
    hidden_act="silu",
    rope_theta=10000.0,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=160, num_experts_per_tok=6, d_ff=1536,
                  num_shared_experts=2, shared_d_ff=1536,
                  capacity_factor=1.25, first_k_dense=1, dense_d_ff=12288),
    source="arXiv:2405.04434; hf",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=32, vocab_size=512,
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16),
        moe=MoEConfig(num_experts=8, num_experts_per_tok=2, d_ff=32,
                      num_shared_experts=1, shared_d_ff=32,
                      capacity_factor=1.5, first_k_dense=1, dense_d_ff=64),
        attn_q_block=32, attn_kv_block=32)
