"""Model + shape configuration dataclasses shared by every architecture.

Every assigned architecture gets one module in ``repro.configs`` exposing
``CONFIG`` (the full published configuration) and ``smoke_config()`` (a reduced
same-family configuration for CPU smoke tests).  ``repro.configs.registry``
maps ``--arch <id>`` to these modules.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    num_experts_per_tok: int
    d_ff: int                      # per-expert hidden width
    num_shared_experts: int = 0
    shared_d_ff: int = 0           # hidden width of the shared expert(s)
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    first_k_dense: int = 0         # leading layers that use a dense FFN
    dense_d_ff: int = 0            # width of those dense layers


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int


@dataclass(frozen=True)
class SSMConfig:
    kind: str                      # "mamba2" | "rwkv6"
    state_dim: int                 # N (mamba2) / head_dim (rwkv6)
    head_dim: int = 64
    expand: int = 2
    conv_kernel: int = 4
    n_groups: int = 1
    chunk: int = 128               # chunked-scan block length


@dataclass(frozen=True)
class FrontendConfig:
    """Modality frontend STUB: input_specs() provides precomputed embeddings."""
    kind: str                      # "vision" | "audio"
    num_tokens: int                # frontend tokens per sample
    embed_dim: int                 # dimensionality delivered by the stub
    # anyres tiling metadata (vision only, informational)
    tiles: int = 0


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    hidden_act: str = "silu"       # silu => SwiGLU, gelu => GeGLU
    qkv_bias: bool = False
    attn_out_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    qk_norm: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): one shared attention block applied every k layers
    hybrid_attn_every: int = 0
    hybrid_attn_heads: int = 0
    # encoder-decoder
    encoder_decoder: bool = False
    num_encoder_layers: int = 0
    frontend: Optional[FrontendConfig] = None
    # numerics
    dtype: str = "bfloat16"
    # MoE dispatch sharding: ep_model (E on model axis) | ep_data_tp_ffn
    # (E on data, expert-FFN hidden on model; serving hillclimb)
    expert_scheme: str = "ep_model"
    # attention implementation knobs (hillclimbable)
    attn_q_block: int = 512
    attn_kv_block: int = 1024
    attn_impl: str = "masked"      # masked | balanced (causal flop-halving)
    remat: str = "none"            # none | block  (rematerialize each layer)
    # citation / provenance string
    source: str = ""

    # ---------------------------------------------------------------- helpers
    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(1, self.num_kv_heads)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str                      # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode
    microbatch: int = 0            # 0 => no gradient accumulation


LM_SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeConfig:
    for s in LM_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


# ----------------------------------------------------------------- accounting
def count_params(cfg: ModelConfig) -> int:
    """Analytic parameter count (matches init_params; used for roofline)."""
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    V = cfg.vocab_size
    n = V * D                                      # embedding
    if not cfg.tie_embeddings:
        n += V * D                                 # lm head

    def attn_params(heads: int, kv_heads: int) -> int:
        p = D * heads * hd + 2 * D * kv_heads * hd + heads * hd * D
        if cfg.qkv_bias:
            p += heads * hd + 2 * kv_heads * hd
        if cfg.qk_norm:
            p += 2 * hd
        return p

    def mla_params() -> int:
        m = cfg.mla
        qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
        p = D * m.q_lora_rank + m.q_lora_rank * H * qk_dim          # q down/up
        p += D * (m.kv_lora_rank + m.qk_rope_head_dim)              # kv down
        p += m.kv_lora_rank * H * (m.qk_nope_head_dim + m.v_head_dim)
        p += H * m.v_head_dim * D                                   # out proj
        p += m.q_lora_rank + m.kv_lora_rank                         # norms
        return p

    def dense_ffn(dff: int) -> int:
        return 3 * D * dff                         # gate, up, down

    def moe_ffn(layer: int) -> int:
        mo = cfg.moe
        if layer < mo.first_k_dense:
            return dense_ffn(mo.dense_d_ff or cfg.d_ff)
        p = D * mo.num_experts                     # router
        p += mo.num_experts * 3 * D * mo.d_ff
        if mo.num_shared_experts:
            p += mo.num_shared_experts * 3 * D * (mo.shared_d_ff or mo.d_ff)
        return p

    def mamba2_layer() -> int:
        s = cfg.ssm
        d_in = s.expand * D
        heads = d_in // s.head_dim
        conv_dim = d_in + 2 * s.n_groups * s.state_dim
        p = D * (2 * d_in + 2 * s.n_groups * s.state_dim + heads)   # in_proj
        p += (s.conv_kernel + 1) * conv_dim                         # conv w+b
        p += heads * 2                                              # A_log, D
        p += heads                                                  # dt_bias
        p += d_in                                                   # gated norm
        p += d_in * D                                               # out_proj
        return p

    def rwkv6_layer() -> int:
        p = 6 * D                                  # mu_base + 5 lerp coefs
        p += D * 5 * 32 + 5 * 32 * D               # ddlerp lora
        p += D + D * 64 + 64 * D                   # w0 + decay lora
        p += 4 * D * D                             # r,k,v,g projections
        p += D                                     # u (bonus)
        p += 2 * D                                 # per-head groupnorm
        p += D * D                                 # output proj
        p += 2 * D                                 # channel-mix lerp coefs
        p += D * cfg.d_ff + cfg.d_ff * D + D * D   # channel mix (k,v,r)
        return p

    per_layer = 2 * D                              # two RMSNorm scales
    if cfg.ssm and cfg.ssm.kind == "mamba2":
        layers = cfg.num_layers * (mamba2_layer() + D)
        if cfg.hybrid_attn_every:
            heads = cfg.hybrid_attn_heads or H
            shared = (2 * D) * heads * hd + 2 * (2 * D) * cfg.num_kv_heads * hd \
                + heads * hd * D + dense_ffn(cfg.d_ff) + 3 * D
            layers += shared                       # one shared block (concat input)
        n += layers + D                            # final norm
        return n
    if cfg.ssm and cfg.ssm.kind == "rwkv6":
        n += cfg.num_layers * (rwkv6_layer() + 2 * D) + 2 * D
        return n

    for layer in range(cfg.num_layers):
        p = per_layer
        p += mla_params() if cfg.mla else attn_params(H, KV)
        p += moe_ffn(layer) if cfg.moe else dense_ffn(cfg.d_ff)
        n += p
    if cfg.encoder_decoder:
        for _ in range(cfg.num_encoder_layers):
            p = per_layer + attn_params(H, KV) + dense_ffn(cfg.d_ff)
            n += p
        # decoder cross-attention blocks + encoder final norm
        n += cfg.num_layers * (attn_params(H, KV) + D) + D
    n += D                                         # final norm
    if cfg.frontend:
        if cfg.encoder_decoder:
            n += cfg.frontend.embed_dim * D        # single linear projector
        else:
            n += cfg.frontend.embed_dim * D + D * D  # 2-layer projector
    return n


def active_params(cfg: ModelConfig) -> int:
    """Activated parameters per token (MoE-aware), for MODEL_FLOPS = 6*N_act*D."""
    if not cfg.moe:
        return count_params(cfg)
    mo = cfg.moe
    full = count_params(cfg)
    all_expert = cfg.num_layers - mo.first_k_dense
    expert_params = all_expert * mo.num_experts * 3 * cfg.d_model * mo.d_ff
    active_expert = all_expert * mo.num_experts_per_tok * 3 * cfg.d_model * mo.d_ff
    return full - expert_params + active_expert


def human(n: float) -> str:
    for unit in ("", "K", "M", "B", "T"):
        if abs(n) < 1000:
            return f"{n:.2f}{unit}"
        n /= 1000
    return f"{n:.2f}P"
