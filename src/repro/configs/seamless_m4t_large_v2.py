"""seamless-m4t-large-v2 — [audio] 24L d_model=1024 16H d_ff=8192
vocab=256206 — enc-dec, multimodal.  [arXiv:2308.11596; hf]

Transformer BACKBONE only: the speech frontend is a STUB — input_specs()
provides precomputed frame embeddings for the 24-layer encoder; the 24-layer
decoder attends to the encoder output via cross-attention.
"""
from repro.configs.base import FrontendConfig, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,                 # decoder layers
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    hidden_act="silu",
    encoder_decoder=True,
    num_encoder_layers=24,
    frontend=FrontendConfig(kind="audio", num_tokens=0, embed_dim=1024),
    source="arXiv:2308.11596; hf",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, num_encoder_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512,
        frontend=FrontendConfig(kind="audio", num_tokens=0, embed_dim=64),
        attn_q_block=32, attn_kv_block=32)
