"""--arch <id> registry for the assigned architectures."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig

_MODULES: Dict[str, str] = {
    "gemma-7b": "repro.configs.gemma_7b",
    "command-r-35b": "repro.configs.command_r_35b",
    "qwen2.5-32b": "repro.configs.qwen2_5_32b",
    "codeqwen1.5-7b": "repro.configs.codeqwen1_5_7b",
    "zamba2-2.7b": "repro.configs.zamba2_2_7b",
    "llava-next-mistral-7b": "repro.configs.llava_next_mistral_7b",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "seamless-m4t-large-v2": "repro.configs.seamless_m4t_large_v2",
    "rwkv6-3b": "repro.configs.rwkv6_3b",
}

ARCH_IDS: List[str] = list(_MODULES)

# archs with a sub-quadratic sequence path: the only ones that run long_500k
SUBQUADRATIC: List[str] = ["zamba2-2.7b", "rwkv6-3b"]


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(_MODULES[arch]).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(_MODULES[arch]).smoke_config()


def shape_applicable(arch: str, shape_name: str) -> bool:
    """Which (arch x shape) cells run.  long_500k is sub-quadratic-only."""
    if shape_name == "long_500k":
        return arch in SUBQUADRATIC
    return True
