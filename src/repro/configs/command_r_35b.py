"""command-r-35b — 40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000,
GQA, no-bias.  [hf:CohereForAI/c4ai-command-r-v01; unverified]

Block structure upstream is [unverified]; we use standard sequential pre-norm
blocks with SwiGLU and no biases (recorded in DESIGN.md §8).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab_size=256000,
    hidden_act="silu",
    qkv_bias=False,
    rope_theta=10000.0,
    tie_embeddings=True,
    source="hf:CohereForAI/c4ai-command-r-v01; unverified",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=64, num_heads=8, num_kv_heads=2, head_dim=8,
        d_ff=160, vocab_size=512, attn_q_block=32, attn_kv_block=32)
