"""llava-next-mistral-7b — [vlm] 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000 — anyres tiling.  [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

The vision frontend is a STUB per the brief: input_specs() provides
precomputed patch embeddings (anyres: base 576 + 4 tiles x 576 = 2880 tokens,
CLIP-L/14 dim 1024) fed through a 2-layer MLP projector into the mistral-7b
backbone.  Mistral's sliding-window attention is modeled as full causal
attention (noted in DESIGN.md §8).
"""
from repro.configs.base import FrontendConfig, ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    hidden_act="silu",
    rope_theta=10000.0,
    frontend=FrontendConfig(kind="vision", num_tokens=2880, embed_dim=1024,
                            tiles=5),
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=64, num_heads=8, num_kv_heads=2, head_dim=8,
        d_ff=160, vocab_size=512,
        frontend=FrontendConfig(kind="vision", num_tokens=16, embed_dim=32,
                                tiles=2),
        attn_q_block=32, attn_kv_block=32)
