from repro.configs.base import (LM_SHAPES, MLAConfig, MoEConfig, ModelConfig,
                                ShapeConfig, SSMConfig, FrontendConfig,
                                active_params, count_params, shape_by_name)
from repro.configs.registry import (ARCH_IDS, SUBQUADRATIC, get_config,
                                    get_smoke_config, shape_applicable)

__all__ = [
    "LM_SHAPES", "MLAConfig", "MoEConfig", "ModelConfig", "ShapeConfig",
    "SSMConfig", "FrontendConfig", "active_params", "count_params",
    "shape_by_name", "ARCH_IDS", "SUBQUADRATIC", "get_config",
    "get_smoke_config", "shape_applicable",
]
