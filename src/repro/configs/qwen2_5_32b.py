"""qwen2.5-32b — 64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064,
GQA, QKV bias.  [hf:Qwen/Qwen2.5-0.5B; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=27648,
    vocab_size=152064,
    hidden_act="silu",
    qkv_bias=True,
    rope_theta=1000000.0,
    source="hf:Qwen/Qwen2.5-0.5B; hf",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=64, num_heads=8, num_kv_heads=2, head_dim=8,
        d_ff=160, vocab_size=512, attn_q_block=32, attn_kv_block=32)
