#!/usr/bin/env python3
"""CI perf gate over BENCH_*.json artifacts (the bench-smoke job).

For every file passed on the command line, checks that prefetching keeps
its headline advantage on the (smoke) config it was run with:

  * serving  (``BENCH_serving*.json``):  ``prefetch.ttft_p99`` must be
    <= ``sync.ttft_p99`` (on-demand staging);
  * windowing (``BENCH_windowing*.json``): for every query present,
    ``deadline.p99`` must be <= ``ondemand.p99`` (and is also reported
    against ``arrival``, informationally — the smoke config is small
    enough that only the on-demand bound is load-bearing);
  * sessions (``BENCH_sessions*.json``): for every query present, the
    session query under prefetch — ``deadline.p99`` (moving-deadline
    re-hints) — must be <= ``ondemand.p99`` (``arrival`` is reported
    informationally; ISSUE 9 acceptance);
  * joins (``BENCH_joins*.json``): for every query present,
    ``twosided.p99`` must be <= ``ondemand.p99`` (``onesided`` is
    reported informationally, same rationale);
  * recovery (``BENCH_recovery*.json``): for every query present,
    warmed recovery's post-restore p99 spike must be <= cold recovery's,
    and the recovered (warmed) run's steady-state p99 must be <= 1.2x
    the unfailed run's steady-state p99 (ISSUE 5 acceptance);
  * obs (``BENCH_obs*.json``): tracing-enabled WALL-CLOCK throughput
    must be >= 0.95x disabled (the observability plane's overhead
    contract, ISSUE 6), the traced run must report a dominant
    critical-path stage, and its hint-quality block must have staged
    hints with precision/recall in (0, 1];
  * hints (``BENCH_hints*.json``): on the Zipf scenario, for every
    query present, selective admission's p99 must be <= all-hints p99,
    and on the distribution-sensitive queries (q5, q20) its wasted-hint
    ratio must be strictly lower (q8's join keys are drawn uniformly
    regardless of ``key_dist``, so it is a structural control — p99
    bound only; ISSUE 7 acceptance);
  * engine (``BENCH_engine*.json``): for every query present, the
    fused data path must beat the interpreted one —
    ``headline.speedup_fused_vs_interpreted`` (fused hot-path capacity
    over interpreted engine tuples/sec, see benchmarks/engine.py) must
    be >= 1, the through-engine pump must hold a parity band (fused >=
    ``PUMP_BAND`` x interpreted: the sim's single-threaded control
    plane serializes with per-batch device dispatch that a deployment
    overlaps, so exact parity is machine-dependent; the band is a
    regression tripwire), and fused full-run p99 must be <= 1.1x
    interpreted (ISSUE 8 acceptance).

Stdlib only:  ``python tools/bench_gate.py BENCH_serving.json ...``
"""
from __future__ import annotations

import json
import sys
from pathlib import Path


def gate_serving(data: dict, fails: list, name: str) -> None:
    sync = data.get("sync")
    pf = data.get("prefetch")
    if not sync or not pf:
        fails.append(f"{name}: missing sync/prefetch results")
        return
    s, p = sync["ttft_p99"], pf["ttft_p99"]
    ok = p <= s
    print(f"  serving: prefetch ttft_p99 {p*1e3:.2f}ms vs on-demand "
          f"{s*1e3:.2f}ms -> {'OK' if ok else 'FAIL'}")
    if not ok:
        fails.append(f"{name}: prefetch ttft_p99 ({p:.4f}s) > on-demand "
                     f"({s:.4f}s)")


def gate_windowing(data: dict, fails: list, name: str) -> None:
    queries = [q for q in data if q != "config"]
    if not queries:
        fails.append(f"{name}: no query results")
    for q in sorted(queries):
        rs = data[q]
        dl, od = rs.get("deadline"), rs.get("ondemand")
        if not dl or not od:
            fails.append(f"{name}: {q} missing deadline/ondemand results")
            continue
        ok = dl["p99"] <= od["p99"]
        arr = rs.get("arrival")
        extra = (f", arrival {arr['p99']*1e3:.2f}ms" if arr else "")
        print(f"  windowing {q}: deadline p99 {dl['p99']*1e3:.2f}ms vs "
              f"on-demand {od['p99']*1e3:.2f}ms{extra} -> "
              f"{'OK' if ok else 'FAIL'}")
        if not ok:
            fails.append(f"{name}: {q} deadline p99 ({dl['p99']:.4f}s) > "
                         f"on-demand ({od['p99']:.4f}s)")


def gate_sessions(data: dict, fails: list, name: str) -> None:
    queries = [q for q in data if q != "config"]
    if not queries:
        fails.append(f"{name}: no query results")
    for q in sorted(queries):
        rs = data[q]
        dl, od = rs.get("deadline"), rs.get("ondemand")
        if not dl or not od:
            fails.append(f"{name}: {q} missing deadline/ondemand results")
            continue
        ok = dl["p99"] <= od["p99"]
        arr = rs.get("arrival")
        extra = (f", arrival {arr['p99']*1e3:.2f}ms" if arr else "")
        print(f"  sessions {q}: deadline p99 {dl['p99']*1e3:.2f}ms vs "
              f"on-demand {od['p99']*1e3:.2f}ms{extra} -> "
              f"{'OK' if ok else 'FAIL'}")
        if not ok:
            fails.append(f"{name}: {q} deadline p99 ({dl['p99']:.4f}s) > "
                         f"on-demand ({od['p99']:.4f}s)")
        if dl.get("rehints", 0) <= 0:
            # the mode must actually exercise moving deadlines, or the
            # p99 bound is testing the wrong thing
            fails.append(f"{name}: {q} deadline mode emitted no re-hints")


def gate_joins(data: dict, fails: list, name: str) -> None:
    queries = [q for q in data if q != "config"]
    if not queries:
        fails.append(f"{name}: no query results")
    for q in sorted(queries):
        rs = data[q]
        two, od = rs.get("twosided"), rs.get("ondemand")
        if not two or not od:
            fails.append(f"{name}: {q} missing twosided/ondemand results")
            continue
        ok = two["p99"] <= od["p99"]
        one = rs.get("onesided")
        extra = (f", onesided {one['p99']*1e3:.2f}ms" if one else "")
        print(f"  joins {q}: twosided p99 {two['p99']*1e3:.2f}ms vs "
              f"on-demand {od['p99']*1e3:.2f}ms{extra} -> "
              f"{'OK' if ok else 'FAIL'}")
        if not ok:
            fails.append(f"{name}: {q} twosided p99 ({two['p99']:.4f}s) > "
                         f"on-demand ({od['p99']:.4f}s)")


def gate_recovery(data: dict, fails: list, name: str) -> None:
    queries = [q for q in data if q != "config"]
    if not queries:
        fails.append(f"{name}: no query results")
    for q in sorted(queries):
        rs = data[q]
        cold, warm = rs.get("cold"), rs.get("warmed")
        unf = rs.get("unfailed")
        if not cold or not warm:
            fails.append(f"{name}: {q} missing cold/warmed results")
            continue
        cs, ws = cold.get("post_restore_p99"), warm.get("post_restore_p99")
        if cs is None or ws is None:
            fails.append(f"{name}: {q} missing post_restore_p99")
            continue
        ok = ws <= cs
        print(f"  recovery {q}: warmed post-restore p99 {ws*1e3:.2f}ms vs "
              f"cold {cs*1e3:.2f}ms -> {'OK' if ok else 'FAIL'}")
        if not ok:
            fails.append(f"{name}: {q} warmed post-restore p99 ({ws:.4f}s)"
                         f" > cold ({cs:.4f}s)")
        if not unf or not unf.get("steady_p99") \
                or not warm.get("steady_p99"):
            # the steady-state rule must never pass vacuously: a stalled
            # catch-up that empties the steady window is itself a failure
            fails.append(f"{name}: {q} missing unfailed/warmed steady_p99"
                         f" — steady-state rule cannot be checked")
            continue
        u, w = unf["steady_p99"], warm["steady_p99"]
        ok = w <= 1.2 * u
        print(f"  recovery {q}: warmed steady p99 {w*1e3:.2f}ms vs "
              f"1.2x unfailed {1.2*u*1e3:.2f}ms -> "
              f"{'OK' if ok else 'FAIL'}")
        if not ok:
            fails.append(f"{name}: {q} warmed steady p99 ({w:.4f}s) > "
                         f"1.2x unfailed ({u:.4f}s)")


def gate_obs(data: dict, fails: list, name: str) -> None:
    dis, tr = data.get("disabled"), data.get("traced")
    if not dis or not tr:
        fails.append(f"{name}: missing disabled/traced results")
        return
    d, t = dis["tuples_per_s"], tr["tuples_per_s"]
    ratio = t / d if d else 0.0
    ok = ratio >= 0.95
    print(f"  obs: traced {t:.0f} tup/s vs disabled {d:.0f} tup/s "
          f"(x{ratio:.3f}, floor 0.95) -> {'OK' if ok else 'FAIL'}")
    if not ok:
        fails.append(f"{name}: traced throughput x{ratio:.3f} of "
                     f"disabled (< 0.95)")
    trace = tr.get("trace", {})
    if not trace.get("dominant_stage"):
        fails.append(f"{name}: traced run has no dominant stage "
                     f"(no spans finished?)")
    hq = tr.get("hint_quality", {})
    prec, rec = hq.get("precision", 0.0), hq.get("recall", 0.0)
    ok = hq.get("staged", 0) > 0 and 0.0 < prec <= 1.0 and 0.0 < rec <= 1.0
    print(f"  obs: staged={hq.get('staged', 0)} precision={prec:.3f} "
          f"recall={rec:.3f} dominant={trace.get('dominant_stage')} -> "
          f"{'OK' if ok else 'FAIL'}")
    if not ok:
        fails.append(f"{name}: hint-quality block empty or degenerate "
                     f"(staged={hq.get('staged', 0)}, precision={prec}, "
                     f"recall={rec})")
    # temporal plane (ISSUE 10): timeline + detectors enabled must also
    # hold the 0.95x overhead floor, and the chaos alert oracle must be
    # sound (zero alerts on golden) and sensitive (every effective
    # injected fault kind matched within the logical delay bound)
    tl = data.get("timeline")
    if not tl:
        fails.append(f"{name}: missing timeline-mode result")
    else:
        r = tl["tuples_per_s"] / d if d else 0.0
        ok = r >= 0.95
        print(f"  obs: timeline {tl['tuples_per_s']:.0f} tup/s "
              f"(x{r:.3f} of disabled, floor 0.95) -> "
              f"{'OK' if ok else 'FAIL'}")
        if not ok:
            fails.append(f"{name}: timeline throughput x{r:.3f} of "
                         f"disabled (< 0.95)")
        ivs = tl.get("timeline", {}).get("intervals", 0)
        if ivs < 2:
            fails.append(f"{name}: timeline run cut {ivs} intervals "
                         f"(plane not ticking?)")
    al = data.get("alerts")
    if not al:
        fails.append(f"{name}: missing alerts (chaos oracle) block")
        return
    recall = al.get("recall", 0.0)
    golden = al.get("golden_alerts", -1)
    stall = al.get("golden_false_stall", -1)
    per_kind = al.get("per_kind", {})
    kinds_ok = all(pk.get("matched", 0) >= 1 for pk in per_kind.values()) \
        and len(per_kind) >= 3
    ok = recall == 1.0 and golden == 0 and stall == 0 and kinds_ok
    print(f"  obs: alert oracle recall={recall:.2f} "
          f"golden_alerts={golden} false_stall={stall} kinds="
          f"{sorted(per_kind)} -> {'OK' if ok else 'FAIL'}")
    if not ok:
        fails.append(f"{name}: alert oracle unsound (recall={recall}, "
                     f"golden_alerts={golden}, false_stall={stall}, "
                     f"per_kind={per_kind})")


# pump parity band (gate_engine): the fused pump shares the sim's
# serialized per-tuple control plane AND pays per-batch device
# dispatch with zero overlap, so it sits below interpreted by a
# machine-dependent margin; the capacity claim lives in the headline
PUMP_BAND = 0.5


def gate_engine(data: dict, fails: list, name: str) -> None:
    queries = [q for q in data if q != "config"]
    if not queries:
        fails.append(f"{name}: no query results")
    for q in sorted(queries):
        h = data[q].get("headline")
        if not h:
            fails.append(f"{name}: {q} missing headline block")
            continue
        sp = h.get("speedup_fused_vs_interpreted", 0.0)
        ok = sp >= 1.0
        print(f"  engine {q}: fused hot path x{sp:.2f} interpreted "
              f"(floor 1.0) -> {'OK' if ok else 'FAIL'}")
        if not ok:
            fails.append(f"{name}: {q} fused hot path x{sp:.2f} "
                         f"interpreted (< 1.0)")
        pr = h.get("pump_ratio_fused_vs_interpreted", 0.0)
        ok = pr >= PUMP_BAND
        print(f"  engine {q}: fused pump x{pr:.2f} interpreted "
              f"(band {PUMP_BAND}) -> {'OK' if ok else 'FAIL'}")
        if not ok:
            fails.append(f"{name}: {q} fused pump x{pr:.2f} interpreted "
                         f"(< {PUMP_BAND})")
        p99 = h.get("p99_ratio_fused_vs_interpreted")
        if p99 is None:
            fails.append(f"{name}: {q} missing p99 ratio")
            continue
        ok = p99 <= 1.1
        print(f"  engine {q}: fused full-run p99 x{p99:.3f} interpreted "
              f"(ceiling 1.1) -> {'OK' if ok else 'FAIL'}")
        if not ok:
            fails.append(f"{name}: {q} fused full-run p99 x{p99:.3f} "
                         f"interpreted (> 1.1)")


# the queries whose key distribution actually follows ``key_dist`` —
# q8 joins persons x auctions on uniformly drawn ids, so selective
# admission cannot (and need not) cut its waste under zipf
DIST_SENSITIVE = ("q5", "q20")


def gate_hints(data: dict, fails: list, name: str) -> None:
    queries = [q for q in data if q != "config"]
    if not queries:
        fails.append(f"{name}: no query results")
    for q in sorted(queries):
        zipf = data[q].get("zipf")
        if not zipf:
            fails.append(f"{name}: {q} missing zipf scenario")
            continue
        rs_all, rs_sel = zipf.get("allhints"), zipf.get("selective")
        if not rs_all or not rs_sel:
            fails.append(f"{name}: {q} zipf missing allhints/selective "
                         f"results")
            continue
        ok = rs_sel["p99"] <= rs_all["p99"]
        print(f"  hints {q}: selective p99 {rs_sel['p99']*1e3:.2f}ms vs "
              f"all-hints {rs_all['p99']*1e3:.2f}ms -> "
              f"{'OK' if ok else 'FAIL'}")
        if not ok:
            fails.append(f"{name}: {q} selective p99 ({rs_sel['p99']:.4f}s)"
                         f" > all-hints ({rs_all['p99']:.4f}s) on zipf")
        if q not in DIST_SENSITIVE:
            continue
        wa, ws = rs_all["wasted_hint_ratio"], rs_sel["wasted_hint_ratio"]
        ok = ws < wa
        print(f"  hints {q}: selective wasted-hint ratio {ws:.3f} vs "
              f"all-hints {wa:.3f} -> {'OK' if ok else 'FAIL'}")
        if not ok:
            fails.append(f"{name}: {q} selective wasted-hint ratio "
                         f"({ws:.3f}) not strictly below all-hints "
                         f"({wa:.3f}) on zipf")


def main(argv) -> int:
    if not argv:
        print("usage: bench_gate.py BENCH_*.json ...")
        return 2
    fails: list = []
    for arg in argv:
        path = Path(arg)
        name = path.name
        if not path.exists():
            fails.append(f"{name}: not found")
            continue
        try:
            data = json.loads(path.read_text())
        except json.JSONDecodeError as e:
            fails.append(f"{name}: invalid JSON ({e})")
            continue
        print(f"{name}:")
        if "serving" in name:
            gate_serving(data, fails, name)
        elif "windowing" in name:
            gate_windowing(data, fails, name)
        elif "sessions" in name:
            gate_sessions(data, fails, name)
        elif "joins" in name:
            gate_joins(data, fails, name)
        elif "recovery" in name:
            gate_recovery(data, fails, name)
        elif "obs" in name:
            gate_obs(data, fails, name)
        elif "hints" in name:
            gate_hints(data, fails, name)
        elif "engine" in name:
            gate_engine(data, fails, name)
        else:
            fails.append(f"{name}: no gate rule for this artifact")
    if fails:
        print("bench gate FAILED:")
        for f in fails:
            print(f"  - {f}")
        return 1
    print("bench gate OK")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
