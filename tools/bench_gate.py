#!/usr/bin/env python3
"""CI perf gate over BENCH_*.json artifacts (the bench-smoke job).

For every file passed on the command line, checks that prefetching keeps
its headline advantage on the (smoke) config it was run with:

  * serving  (``BENCH_serving*.json``):  ``prefetch.ttft_p99`` must be
    <= ``sync.ttft_p99`` (on-demand staging);
  * windowing (``BENCH_windowing*.json``): for every query present,
    ``deadline.p99`` must be <= ``ondemand.p99`` (and is also reported
    against ``arrival``, informationally — the smoke config is small
    enough that only the on-demand bound is load-bearing);
  * joins (``BENCH_joins*.json``): for every query present,
    ``twosided.p99`` must be <= ``ondemand.p99`` (``onesided`` is
    reported informationally, same rationale).

Stdlib only:  ``python tools/bench_gate.py BENCH_serving.json ...``
"""
from __future__ import annotations

import json
import sys
from pathlib import Path


def gate_serving(data: dict, fails: list, name: str) -> None:
    sync = data.get("sync")
    pf = data.get("prefetch")
    if not sync or not pf:
        fails.append(f"{name}: missing sync/prefetch results")
        return
    s, p = sync["ttft_p99"], pf["ttft_p99"]
    ok = p <= s
    print(f"  serving: prefetch ttft_p99 {p*1e3:.2f}ms vs on-demand "
          f"{s*1e3:.2f}ms -> {'OK' if ok else 'FAIL'}")
    if not ok:
        fails.append(f"{name}: prefetch ttft_p99 ({p:.4f}s) > on-demand "
                     f"({s:.4f}s)")


def gate_windowing(data: dict, fails: list, name: str) -> None:
    queries = [q for q in data if q != "config"]
    if not queries:
        fails.append(f"{name}: no query results")
    for q in sorted(queries):
        rs = data[q]
        dl, od = rs.get("deadline"), rs.get("ondemand")
        if not dl or not od:
            fails.append(f"{name}: {q} missing deadline/ondemand results")
            continue
        ok = dl["p99"] <= od["p99"]
        arr = rs.get("arrival")
        extra = (f", arrival {arr['p99']*1e3:.2f}ms" if arr else "")
        print(f"  windowing {q}: deadline p99 {dl['p99']*1e3:.2f}ms vs "
              f"on-demand {od['p99']*1e3:.2f}ms{extra} -> "
              f"{'OK' if ok else 'FAIL'}")
        if not ok:
            fails.append(f"{name}: {q} deadline p99 ({dl['p99']:.4f}s) > "
                         f"on-demand ({od['p99']:.4f}s)")


def gate_joins(data: dict, fails: list, name: str) -> None:
    queries = [q for q in data if q != "config"]
    if not queries:
        fails.append(f"{name}: no query results")
    for q in sorted(queries):
        rs = data[q]
        two, od = rs.get("twosided"), rs.get("ondemand")
        if not two or not od:
            fails.append(f"{name}: {q} missing twosided/ondemand results")
            continue
        ok = two["p99"] <= od["p99"]
        one = rs.get("onesided")
        extra = (f", onesided {one['p99']*1e3:.2f}ms" if one else "")
        print(f"  joins {q}: twosided p99 {two['p99']*1e3:.2f}ms vs "
              f"on-demand {od['p99']*1e3:.2f}ms{extra} -> "
              f"{'OK' if ok else 'FAIL'}")
        if not ok:
            fails.append(f"{name}: {q} twosided p99 ({two['p99']:.4f}s) > "
                         f"on-demand ({od['p99']:.4f}s)")


def main(argv) -> int:
    if not argv:
        print("usage: bench_gate.py BENCH_*.json ...")
        return 2
    fails: list = []
    for arg in argv:
        path = Path(arg)
        name = path.name
        if not path.exists():
            fails.append(f"{name}: not found")
            continue
        try:
            data = json.loads(path.read_text())
        except json.JSONDecodeError as e:
            fails.append(f"{name}: invalid JSON ({e})")
            continue
        print(f"{name}:")
        if "serving" in name:
            gate_serving(data, fails, name)
        elif "windowing" in name:
            gate_windowing(data, fails, name)
        elif "joins" in name:
            gate_joins(data, fails, name)
        else:
            fails.append(f"{name}: no gate rule for this artifact")
    if fails:
        print("bench gate FAILED:")
        for f in fails:
            print(f"  - {f}")
        return 1
    print("bench gate OK")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
