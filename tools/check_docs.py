#!/usr/bin/env python3
"""Docs consistency check (the CI docs job).

Fails when:
  * a relative markdown link in any root-level ``*.md`` points at a file
    that does not exist;
  * ``README.md`` references a ``BENCH_*.json`` artifact that is not
    checked in at the repo root;
  * a checked-in ``BENCH_*.json`` is NOT referenced from ``README.md``
    (every artifact must appear in the regeneration table), or README
    never names the ``benchmarks/<name>.py`` script that regenerates it
    (the regeneration COMMAND is part of the contract);
  * ``README.md`` references a module path (``repro.x.y``) or a
    repo-relative file path in backticks that does not exist;
  * a ``DESIGN.md §N`` citation in any ``.py`` file (src/, tools/,
    benchmarks/, tests/, examples/) names a section with no matching
    ``## §N`` heading in ``DESIGN.md``;
  * a checked-in ``BENCH_*.json`` is unparseable, empty, or missing its
    ``config`` block / result entries (schema check);
  * a backticked metric name in DESIGN.md's §12 section (dotted,
    ``engine.sink.latency``-style, ``<x>`` wildcards allowed) is not a
    template in ``repro.obs.METRIC_CATALOG`` — the metric table and the
    registry catalog must stay in lockstep;
  * ``CHANGES.md`` lacks an entry for the current PR number (taken from
    the ``# ISSUE <n>`` heading of ``ISSUE.md``, when present).

Stdlib only — runs anywhere Python does:  ``python tools/check_docs.py``
"""
from __future__ import annotations

import json
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
BENCH_RE = re.compile(r"BENCH_\w+\.json")
CODE_RE = re.compile(r"`([^`\n]+)`")
MODULE_RE = re.compile(r"repro(?:\.\w+)+")
# a backticked token is treated as a repo path only when it looks like one
PATH_RE = re.compile(r"[\w.-]+(?:/[\w.-]+)+/?|[\w-]+\.(?:py|md|json|ini|"
                     r"toml|txt|yml|yaml)")


def path_exists(rel: str) -> bool:
    rel = rel.rstrip("/")
    return any((base / rel).exists()
               for base in (ROOT, ROOT / "src", ROOT / "src" / "repro"))


def module_exists(dotted: str) -> bool:
    stem = ROOT / "src" / Path(*dotted.split("."))
    return stem.is_dir() or stem.with_suffix(".py").exists()


def check_links(md: Path, fails: list) -> None:
    for target in LINK_RE.findall(md.read_text()):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#")[0]
        if rel and not (md.parent / rel).exists():
            fails.append(f"{md.name}: broken link -> {target}")


def check_readme(readme: Path, fails: list) -> None:
    text = readme.read_text()
    for bench in sorted(set(BENCH_RE.findall(text))):
        if not (ROOT / bench).exists():
            fails.append(f"README.md: references {bench}, which does not "
                         f"exist (regenerate it or drop the reference)")
    for code in sorted(set(CODE_RE.findall(text))):
        for dotted in MODULE_RE.findall(code):
            if not module_exists(dotted):
                fails.append(f"README.md: module `{dotted}` not found "
                             f"under src/")
        if MODULE_RE.fullmatch(code):
            continue
        m = PATH_RE.fullmatch(code)
        if m and "//" not in code and not path_exists(code):
            fails.append(f"README.md: path `{code}` does not exist")


CITE_RE = re.compile(r"DESIGN\.md\s*§(\d+)")
SECTION_RE = re.compile(r"^##\s*§(\d+)\b", re.M)
PY_DIRS = ("src", "tools", "benchmarks", "tests", "examples")


def check_design_citations(fails: list) -> int:
    """Every ``DESIGN.md §N`` citation in a ``.py`` file must resolve to
    a real ``## §N`` section heading of DESIGN.md."""
    design = ROOT / "DESIGN.md"
    sections = set(SECTION_RE.findall(design.read_text())) \
        if design.exists() else set()
    n_cites = 0
    for d in PY_DIRS:
        for py in sorted((ROOT / d).rglob("*.py")):
            for num in CITE_RE.findall(py.read_text()):
                n_cites += 1
                if num not in sections:
                    fails.append(
                        f"{py.relative_to(ROOT)}: cites DESIGN.md §{num}, "
                        f"but DESIGN.md has no '## §{num}' heading")
    if design.exists() and not sections:
        fails.append("DESIGN.md: no '## §N' section headings found")
    return n_cites


def check_bench_referenced(readme: Path, fails: list) -> None:
    """Every checked-in BENCH_*.json must be referenced from README.md
    (the regeneration table is the contract for how to rebuild it), and
    the row must name the ``benchmarks/<name>.py`` script so the rebuild
    command resolves."""
    text = readme.read_text() if readme.exists() else ""
    for path in sorted(ROOT.glob("BENCH_*.json")):
        if path.name not in text:
            fails.append(f"{path.name}: checked in but never referenced "
                         f"from README.md — add a regeneration-table row")
            continue
        script = f"benchmarks/{path.stem.split('_', 1)[1]}.py"
        if script not in text:
            fails.append(f"{path.name}: README.md never names {script} — "
                         f"add the regeneration command to its row")
        elif not (ROOT / script).exists():
            fails.append(f"{path.name}: regeneration script {script} "
                         f"does not exist")


def check_bench_schemas(fails: list) -> int:
    """Every checked-in BENCH_*.json must be parseable, non-empty, carry a
    ``config`` block, and at least one non-config result entry."""
    n = 0
    for path in sorted(ROOT.glob("BENCH_*.json")):
        n += 1
        try:
            data = json.loads(path.read_text())
        except json.JSONDecodeError as e:
            fails.append(f"{path.name}: invalid JSON ({e})")
            continue
        if not isinstance(data, dict) or not data:
            fails.append(f"{path.name}: expected a non-empty JSON object")
            continue
        if "config" not in data:
            fails.append(f"{path.name}: missing top-level 'config'")
        if not [k for k in data if k != "config"]:
            fails.append(f"{path.name}: no result entries besides 'config'")
    return n


METRIC_RE = re.compile(
    r"(?:[a-z0-9_]+|<[a-z_]+>)(?:\.(?:[a-z0-9_]+|<[a-z_]+>)){1,}")


def check_metric_catalog(fails: list) -> int:
    """Every backticked metric-name template cited in DESIGN.md's
    observability sections (§12, and §16's temporal plane) must exist
    in ``repro.obs.METRIC_CATALOG`` (the registry's name contract).
    ``repro.obs.registry`` is deliberately stdlib-only so this check
    runs in the docs job without the jax toolchain."""
    design = ROOT / "DESIGN.md"
    if not design.exists():
        return 0
    text = design.read_text()
    sections = [(sec, m.group(0)) for sec in ("§12", "§16")
                for m in [re.search(rf"^##\s*{sec}\b.*?(?=^##\s|\Z)",
                                    text, re.M | re.S)]
                if m is not None]
    if not sections:
        return 0
    sys.path.insert(0, str(ROOT / "src"))
    try:
        from repro.obs.registry import METRIC_CATALOG
    except Exception as e:                  # pragma: no cover
        fails.append(f"DESIGN.md: cannot import repro.obs.registry "
                     f"to verify metric names ({e})")
        return 0
    n = 0
    for sec, body in sections:
        found = 0
        for code in CODE_RE.findall(body):
            if not METRIC_RE.fullmatch(code):
                continue                    # not a metric-shaped token
            if code.startswith("repro.") or code.rsplit(".", 1)[-1] in (
                    "py", "md", "json", "jsonl", "yml", "yaml", "ini",
                    "toml", "txt"):
                continue                    # module / file path, not a metric
            found += 1
            if code not in METRIC_CATALOG:
                fails.append(f"DESIGN.md {sec}: metric `{code}` is not "
                             f"in repro.obs.METRIC_CATALOG — fix the "
                             f"table or add the template")
        if found == 0:
            fails.append(f"DESIGN.md {sec}: no backticked metric names "
                         f"found — the metric table is part of the "
                         f"{sec} contract")
        n += found
    return n


def check_changes(fails: list) -> None:
    """CHANGES.md must have an entry for the PR this tree is building
    (the ``# ISSUE <n>`` heading of ISSUE.md names it)."""
    changes = ROOT / "CHANGES.md"
    if not changes.exists():
        fails.append("CHANGES.md is missing")
        return
    issue = ROOT / "ISSUE.md"
    if not issue.exists():
        return
    m = re.search(r"^#\s*ISSUE\s+(\d+)", issue.read_text(), re.M)
    if m is None:
        return
    n = m.group(1)
    if not re.search(rf"^PR {n}:", changes.read_text(), re.M):
        fails.append(f"CHANGES.md: no 'PR {n}:' entry for the current "
                     f"ISSUE ({n}) — append one describing this PR")


def main() -> int:
    fails: list = []
    md_files = sorted(ROOT.glob("*.md"))
    if not any(md.name == "README.md" for md in md_files):
        fails.append("README.md is missing")
    for md in md_files:
        check_links(md, fails)
    readme = ROOT / "README.md"
    if readme.exists():
        check_readme(readme, fails)
    check_bench_referenced(readme, fails)
    n_bench = check_bench_schemas(fails)
    n_cites = check_design_citations(fails)
    n_metrics = check_metric_catalog(fails)
    check_changes(fails)
    if fails:
        print("docs check FAILED:")
        for f in fails:
            print(f"  - {f}")
        return 1
    print(f"docs check OK ({len(md_files)} markdown files, "
          f"{n_bench} BENCH artifacts, {n_cites} DESIGN citations, "
          f"{n_metrics} §12/§16 metric names)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
