#!/usr/bin/env python3
"""Docs consistency check (the CI docs job).

Fails when:
  * a relative markdown link in any root-level ``*.md`` points at a file
    that does not exist;
  * ``README.md`` references a ``BENCH_*.json`` artifact that is not
    checked in at the repo root;
  * ``README.md`` references a module path (``repro.x.y``) or a
    repo-relative file path in backticks that does not exist.

Stdlib only — runs anywhere Python does:  ``python tools/check_docs.py``
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
BENCH_RE = re.compile(r"BENCH_\w+\.json")
CODE_RE = re.compile(r"`([^`\n]+)`")
MODULE_RE = re.compile(r"repro(?:\.\w+)+")
# a backticked token is treated as a repo path only when it looks like one
PATH_RE = re.compile(r"[\w.-]+(?:/[\w.-]+)+/?|[\w-]+\.(?:py|md|json|ini|"
                     r"toml|txt|yml|yaml)")


def path_exists(rel: str) -> bool:
    rel = rel.rstrip("/")
    return any((base / rel).exists()
               for base in (ROOT, ROOT / "src", ROOT / "src" / "repro"))


def module_exists(dotted: str) -> bool:
    stem = ROOT / "src" / Path(*dotted.split("."))
    return stem.is_dir() or stem.with_suffix(".py").exists()


def check_links(md: Path, fails: list) -> None:
    for target in LINK_RE.findall(md.read_text()):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#")[0]
        if rel and not (md.parent / rel).exists():
            fails.append(f"{md.name}: broken link -> {target}")


def check_readme(readme: Path, fails: list) -> None:
    text = readme.read_text()
    for bench in sorted(set(BENCH_RE.findall(text))):
        if not (ROOT / bench).exists():
            fails.append(f"README.md: references {bench}, which does not "
                         f"exist (regenerate it or drop the reference)")
    for code in sorted(set(CODE_RE.findall(text))):
        for dotted in MODULE_RE.findall(code):
            if not module_exists(dotted):
                fails.append(f"README.md: module `{dotted}` not found "
                             f"under src/")
        if MODULE_RE.fullmatch(code):
            continue
        m = PATH_RE.fullmatch(code)
        if m and "//" not in code and not path_exists(code):
            fails.append(f"README.md: path `{code}` does not exist")


def main() -> int:
    fails: list = []
    md_files = sorted(ROOT.glob("*.md"))
    if not any(md.name == "README.md" for md in md_files):
        fails.append("README.md is missing")
    for md in md_files:
        check_links(md, fails)
    readme = ROOT / "README.md"
    if readme.exists():
        check_readme(readme, fails)
    if fails:
        print("docs check FAILED:")
        for f in fails:
            print(f"  - {f}")
        return 1
    print(f"docs check OK ({len(md_files)} markdown files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
