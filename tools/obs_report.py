#!/usr/bin/env python3
"""Render the observability plane's view of a run (DESIGN.md §12).

Two modes:

  * default — build the q5 smoke pipeline (same config as the windowing
    benchmark's smoke tier), run it with per-tuple tracing enabled, and
    print the critical-path latency breakdown: a per-stage table (count,
    mean, p50, p99, total, share) with the DOMINANT stage flagged, the
    hint-quality block (staged/used/wasted/late, precision, recall,
    signed lead-time percentiles), and the eviction-reason split;
  * ``--snapshot FILE.jsonl`` — read a registry export produced by
    ``Engine.enable_export`` and print the last snapshot's metrics
    (optionally filtered by ``--grep SUBSTRING``), plus the delta of
    every counter between the first and last lines.

    PYTHONPATH=src python tools/obs_report.py
    PYTHONPATH=src python tools/obs_report.py --snapshot run.jsonl --grep prefetch
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def fmt_s(v: float) -> str:
    """Seconds -> aligned ms string (lead times may be negative)."""
    return f"{v * 1e3:9.3f}ms"


def print_stage_table(trace: dict) -> None:
    from repro.obs import STAGES
    dom = trace.get("dominant_stage")
    print(f"\ncritical-path stages ({trace.get('finished', 0)} sampled "
          f"spans; probe hit/miss "
          f"{trace.get('probe_hits', 0)}/{trace.get('probe_misses', 0)}):")
    hdr = (f"  {'stage':<12s} {'count':>7s} {'mean':>11s} {'p50':>11s} "
           f"{'p99':>11s} {'total':>10s} {'share':>6s}")
    print(hdr)
    print("  " + "-" * (len(hdr) - 2))
    for s in STAGES:
        st = trace.get(s)
        if not st:
            continue
        flag = "  <- dominant" if s == dom else ""
        print(f"  {s:<12s} {st['count']:>7d} {fmt_s(st['mean'])} "
              f"{fmt_s(st['p50'])} {fmt_s(st['p99'])} "
              f"{st['total']:>9.3f}s {st['share']:>6.1%}{flag}")
    if dom:
        print(f"  dominant stage: {dom} "
              f"({trace[dom]['share']:.0%} of sampled critical-path time)")


def print_quality(hq: dict, evictions: dict) -> None:
    print("\nhint quality:")
    for k in ("staged", "used", "wasted", "late", "late_watermark",
              "duplicate", "resident_unused"):
        if k in hq:
            print(f"  {k:<16s} {hq[k]:>8d}")
    print(f"  {'precision':<16s} {hq.get('precision', 0.0):>8.3f}   "
          f"(used / staged+late)")
    print(f"  {'recall':<16s} {hq.get('recall', 0.0):>8.3f}   "
          f"(prefetch hits / all fetches)")
    if "lead_p50" in hq:
        print(f"  lead time p50 {fmt_s(hq['lead_p50'])}  "
              f"p99 {fmt_s(hq['lead_p99'])}  "
              f"min {fmt_s(hq['lead_min'])}  max {fmt_s(hq['lead_max'])}"
              f"   (negative = staged too late)")
    if evictions:
        print("\nevictions (reason.admission):")
        for k in sorted(evictions):
            print(f"  {k:<24s} {evictions[k]:>8d}")


def print_fused(fb: dict) -> None:
    """Fused hot-path rollup (DESIGN.md §14): batch-fill is the one to
    watch — underfilled batches waste launch cost (fences and drain
    stalls fragment them)."""
    if not fb:
        return
    print("\nfused hot path:")
    print(f"  {'batches':<16s} {fb.get('batches', 0):>8d}")
    print(f"  {'lanes':<16s} {fb.get('lanes', 0):>8d}")
    print(f"  {'batch-fill':<16s} {fb.get('fill_ratio', 0.0):>8.3f}   "
          f"(lanes / batches x width)")
    print(f"  {'device hits':<16s} {fb.get('device_hits', 0):>8d}")
    print(f"  {'device misses':<16s} {fb.get('device_misses', 0):>8d}")


def run_report(args) -> int:
    from repro.streaming.backend import LOCAL_NVME
    from repro.streaming.nexmark import NexmarkConfig, build_query

    cfg = NexmarkConfig(rate=5_000.0, active_window=1.0, oo_bound=0.3,
                        seed=args.seed)
    eng = build_query("q5", "tac", "prefetch", cfg,
                      cache_entries=256, backend=LOCAL_NVME,
                      parallelism=2, source_parallelism=1, io_workers=4,
                      buffer_timeout=0.002, hint_ts="deadline",
                      window_size=1.0, window_slide=0.5,
                      fused=args.fused)
    eng.enable_tracing(sample_every=args.sample_every)
    if args.export:
        eng.enable_export(args.export, interval=0.5)
    m = eng.run(duration=args.duration, warmup=args.warmup)

    print(f"q5 smoke (deadline hints, {args.duration:.0f}s sim, "
          f"1-in-{args.sample_every} tracing):")
    print(f"  outputs {m['n_outputs']}  p50 {fmt_s(m['p50']).strip()}  "
          f"p99 {fmt_s(m['p99']).strip()}  "
          f"hit rate {m.get('stateful_hit_rate', 0.0):.2f}")
    print_stage_table(m.get("trace", {}))
    print_quality(m.get("stateful_hint_quality", {}),
                  m.get("stateful_evictions", {}))
    print_fused(m.get("stateful_fused", {}))
    if args.export:
        print(f"\nregistry snapshots appended to {args.export}")
    return 0


def snapshot_report(path: str, grep: str) -> int:
    lines = []
    with open(path) as f:
        for raw in f:
            raw = raw.strip()
            if raw:
                lines.append(json.loads(raw))
    if not lines:
        print(f"{path}: no snapshots")
        return 1
    first, last = lines[0]["metrics"], lines[-1]["metrics"]
    print(f"{path}: {len(lines)} snapshots, "
          f"t={lines[0]['t']}..{lines[-1]['t']}")
    for name in sorted(last):
        if grep and grep not in name:
            continue
        v = last[name]
        if isinstance(v, dict):        # histogram summary
            print(f"  {name:<44s} count={v.get('count', 0):>7} "
                  f"mean={v.get('mean', 0.0):.6g} "
                  f"p99={v.get('p99', 0.0):.6g}")
        else:
            d = v - first.get(name, 0) if isinstance(v, (int, float)) \
                and isinstance(first.get(name), (int, float)) else None
            delta = f" (+{d:g})" if d else ""
            print(f"  {name:<44s} {v:g}{delta}")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--snapshot", metavar="FILE.jsonl",
                    help="report on a registry JSONL export instead of "
                         "running the q5 smoke pipeline")
    ap.add_argument("--grep", default="",
                    help="with --snapshot: only metrics containing this")
    ap.add_argument("--duration", type=float, default=3.0)
    ap.add_argument("--warmup", type=float, default=1.5)
    ap.add_argument("--sample-every", type=int, default=16)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--fused", action="store_true",
                    help="run the q5 smoke pipeline on the fused device "
                         "hot path and report its batch-fill ratio")
    ap.add_argument("--export", metavar="FILE.jsonl",
                    help="also append registry snapshots during the run")
    args = ap.parse_args()
    if args.snapshot:
        return snapshot_report(args.snapshot, args.grep)
    return run_report(args)


if __name__ == "__main__":
    sys.exit(main())
