#!/usr/bin/env python3
"""Render the observability plane's view of a run (DESIGN.md §12, §16).

Three modes:

  * default — build the q5 smoke pipeline (same config as the windowing
    benchmark's smoke tier), run it with per-tuple tracing enabled, and
    print the critical-path latency breakdown: a per-stage table (count,
    mean, p50, p99, total, share) with the DOMINANT stage flagged, the
    hint-quality block (staged/used/wasted/late, precision, recall,
    signed lead-time percentiles), and the eviction-reason split;
  * ``--timeline`` — run the same pipeline with the temporal plane
    enabled (DESIGN.md §16) and print the per-interval view: precision,
    recall, watermark lag, and hit-rate series on the logical clock with
    sparklines, plus every health alert the detectors raised.
    ``--since``/``--until`` restrict the printed window (logical time);
  * ``--snapshot FILE.jsonl`` — read a registry export produced by
    ``Engine.enable_export`` and print the last snapshot's metrics
    (optionally filtered by ``--grep SUBSTRING``).  Exports carry a
    per-line ``delta`` block since PR 10; the report sums it for the
    interval-rate column and falls back to diffing first/last lines on
    legacy cumulative-only files.

    PYTHONPATH=src python tools/obs_report.py
    PYTHONPATH=src python tools/obs_report.py --timeline --since 1.0
    PYTHONPATH=src python tools/obs_report.py --snapshot run.jsonl --grep prefetch
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def fmt_s(v: float) -> str:
    """Seconds -> aligned ms string (lead times may be negative)."""
    return f"{v * 1e3:9.3f}ms"


def print_stage_table(trace: dict) -> None:
    from repro.obs import STAGES
    dom = trace.get("dominant_stage")
    print(f"\ncritical-path stages ({trace.get('finished', 0)} sampled "
          f"spans; probe hit/miss "
          f"{trace.get('probe_hits', 0)}/{trace.get('probe_misses', 0)}):")
    hdr = (f"  {'stage':<12s} {'count':>7s} {'mean':>11s} {'p50':>11s} "
           f"{'p99':>11s} {'total':>10s} {'share':>6s}")
    print(hdr)
    print("  " + "-" * (len(hdr) - 2))
    for s in STAGES:
        st = trace.get(s)
        if not st:
            continue
        flag = "  <- dominant" if s == dom else ""
        print(f"  {s:<12s} {st['count']:>7d} {fmt_s(st['mean'])} "
              f"{fmt_s(st['p50'])} {fmt_s(st['p99'])} "
              f"{st['total']:>9.3f}s {st['share']:>6.1%}{flag}")
    if dom:
        print(f"  dominant stage: {dom} "
              f"({trace[dom]['share']:.0%} of sampled critical-path time)")


def print_quality(hq: dict, evictions: dict) -> None:
    print("\nhint quality:")
    for k in ("staged", "used", "wasted", "late", "late_watermark",
              "duplicate", "resident_unused"):
        if k in hq:
            print(f"  {k:<16s} {hq[k]:>8d}")
    print(f"  {'precision':<16s} {hq.get('precision', 0.0):>8.3f}   "
          f"(used / staged+late)")
    print(f"  {'recall':<16s} {hq.get('recall', 0.0):>8.3f}   "
          f"(prefetch hits / all fetches)")
    if "lead_p50" in hq:
        print(f"  lead time p50 {fmt_s(hq['lead_p50'])}  "
              f"p99 {fmt_s(hq['lead_p99'])}  "
              f"min {fmt_s(hq['lead_min'])}  max {fmt_s(hq['lead_max'])}"
              f"   (negative = staged too late)")
    if evictions:
        print("\nevictions (reason.admission):")
        for k in sorted(evictions):
            print(f"  {k:<24s} {evictions[k]:>8d}")


def print_fused(fb: dict) -> None:
    """Fused hot-path rollup (DESIGN.md §14): batch-fill is the one to
    watch — underfilled batches waste launch cost (fences and drain
    stalls fragment them)."""
    if not fb:
        return
    print("\nfused hot path:")
    print(f"  {'batches':<16s} {fb.get('batches', 0):>8d}")
    print(f"  {'lanes':<16s} {fb.get('lanes', 0):>8d}")
    print(f"  {'batch-fill':<16s} {fb.get('fill_ratio', 0.0):>8.3f}   "
          f"(lanes / batches x width)")
    print(f"  {'device hits':<16s} {fb.get('device_hits', 0):>8d}")
    print(f"  {'device misses':<16s} {fb.get('device_misses', 0):>8d}")
    print(f"  {'conflicts':<16s} {fb.get('device_conflicts', 0):>8d}   "
          f"(misses beyond free device slots at adjudication)")


SPARK = "▁▂▃▄▅▆▇█"


def sparkline(vals, lo=None, hi=None) -> str:
    """Unicode block sparkline; bounds default to the series extremes."""
    if not vals:
        return "(no data)"
    lo = min(vals) if lo is None else lo
    hi = max(vals) if hi is None else hi
    span = hi - lo
    if span <= 0:
        return SPARK[0] * len(vals)
    return "".join(
        SPARK[min(len(SPARK) - 1, int((v - lo) / span * len(SPARK)))]
        for v in vals)


def _build_smoke(args):
    from repro.streaming.backend import LOCAL_NVME
    from repro.streaming.nexmark import NexmarkConfig, build_query

    cfg = NexmarkConfig(rate=5_000.0, active_window=1.0, oo_bound=0.3,
                        seed=args.seed)
    kw = dict(cache_entries=256, backend=LOCAL_NVME, parallelism=2,
              source_parallelism=1, io_workers=4, buffer_timeout=0.002,
              hint_ts="deadline", fused=args.fused)
    if args.query == "q20":
        return build_query("q20", "tac", "prefetch", cfg, **kw)
    return build_query("q5", "tac", "prefetch", cfg, window_size=1.0,
                       window_slide=0.5, **kw)


def run_report(args) -> int:
    eng = _build_smoke(args)
    eng.enable_tracing(sample_every=args.sample_every)
    if args.export:
        eng.enable_export(args.export, interval=0.5)
    m = eng.run(duration=args.duration, warmup=args.warmup)

    print(f"{args.query} smoke (deadline hints, {args.duration:.0f}s sim, "
          f"1-in-{args.sample_every} tracing):")
    print(f"  outputs {m['n_outputs']}  p50 {fmt_s(m['p50']).strip()}  "
          f"p99 {fmt_s(m['p99']).strip()}  "
          f"hit rate {m.get('stateful_hit_rate', 0.0):.2f}")
    print_stage_table(m.get("trace", {}))
    print_quality(m.get("stateful_hint_quality", {}),
                  m.get("stateful_evictions", {}))
    print_fused(m.get("stateful_fused", {}))
    if args.export:
        print(f"\nregistry snapshots appended to {args.export}")
    return 0


def timeline_report(args) -> int:
    """Per-interval view of the smoke run on the logical clock
    (DESIGN.md §16): precision / recall / watermark-lag / hit-rate
    series with sparklines, plus the detectors' alerts."""
    eng = _build_smoke(args)
    eng.enable_timeline(interval=args.interval)
    m = eng.run(duration=args.duration, warmup=args.warmup)
    tl = eng.timeline
    since, until = args.since, args.until
    ivs = tl.select(since, until)
    b = tl.block()
    print(f"{args.query} smoke timeline ({args.duration:.0f}s sim, "
          f"interval {tl.interval:g}s): {b['intervals']} intervals cut, "
          f"{len(ivs)} in window, {b['evicted']} evicted "
          f"(ring capacity {b['capacity']})")
    print(f"  outputs {m['n_outputs']}  "
          f"hit rate {m.get('stateful_hit_rate', 0.0):.2f}")
    for op in (eng.health.ops if eng.health else []):
        pre = f"engine.{op}"
        prec = tl.ratio_series(f"{pre}.prefetch.used",
                               (f"{pre}.prefetch.staged",
                                f"{pre}.prefetch.late"),
                               min_den=1.0, since=since, until=until)
        rec = tl.ratio_series(f"{pre}.prefetch.hits",
                              (f"{pre}.prefetch.hits",
                               f"{pre}.prefetch.demand_fetches"),
                              min_den=1.0, since=since, until=until)
        hit = tl.ratio_series(f"{pre}.cache.hits",
                              (f"{pre}.cache.hits",
                               f"{pre}.cache.misses"),
                              min_den=1.0, since=since, until=until)
        lag = tl.series(f"{pre}.watermark.lag", since=since, until=until)
        fill = tl.series(f"{pre}.fused.fill_ratio", since=since,
                         until=until)
        print(f"\n  operator {op!r} per-interval series "
              f"([{'start' if since is None else f'{since:g}s'} .. "
              f"{'end' if until is None else f'{until:g}s'}]):")

        def row(label, s, lo=None, hi=None, unit=""):
            if not s:
                print(f"    {label:<14s} (no data in window)")
                return
            vals = [v for _, v in s]
            print(f"    {label:<14s} {sparkline(vals, lo, hi)}  "
                  f"last={vals[-1]:.3f}{unit}  "
                  f"min={min(vals):.3f}  max={max(vals):.3f}")

        row("precision", prec, 0.0, 1.0)
        row("recall", rec, 0.0, 1.0)
        row("hit-rate", hit, 0.0, 1.0)
        row("wm lag", lag, unit="s")
        if args.fused:
            row("fused fill", fill, 0.0, 1.0)
    alerts = [a for a in (eng.health.alerts if eng.health else [])
              if (since is None or a.t >= since)
              and (until is None or a.t <= until)]
    if alerts:
        print(f"\n  alerts ({len(alerts)}):")
        for a in alerts:
            cl = "active" if a.cleared_t is None \
                else f"cleared@{a.cleared_t:.2f}s"
            print(f"    [{a.t:6.2f}s] {a.kind:<10s} op={a.op} "
                  f"value={a.value:.4g} ({cl}) — {a.message}")
    else:
        print("\n  alerts: none (healthy run)")
    if args.export:
        from repro.obs import timeline_jsonl
        n = timeline_jsonl(tl, args.export,
                           alerts=eng.health.alerts if eng.health else None)
        print(f"\n  {n} timeline records appended to {args.export}")
    return 0


def snapshot_report(path: str, grep: str) -> int:
    lines = []
    with open(path) as f:
        for raw in f:
            raw = raw.strip()
            if raw:
                lines.append(json.loads(raw))
    if not lines:
        print(f"{path}: no snapshots")
        return 1
    first, last = lines[0]["metrics"], lines[-1]["metrics"]
    # post-PR-10 exports carry an explicit per-line ``delta`` block;
    # summing it across lines gives the counter's total change over the
    # export window without diffing cumulative snapshots by hand
    have_delta = all("delta" in ln for ln in lines)
    summed: dict = {}
    if have_delta:
        for ln in lines:
            for n, d in ln["delta"].items():
                summed[n] = summed.get(n, 0) + d
    print(f"{path}: {len(lines)} snapshots, "
          f"t={lines[0]['t']}..{lines[-1]['t']}"
          f"{' (interval deltas)' if have_delta else ' (legacy cumulative)'}")
    for name in sorted(last):
        if grep and grep not in name:
            continue
        v = last[name]
        if isinstance(v, dict):        # histogram summary
            print(f"  {name:<44s} count={v.get('count', 0):>7} "
                  f"mean={v.get('mean', 0.0):.6g} "
                  f"p99={v.get('p99', 0.0):.6g}")
        else:
            if have_delta and name in summed:
                d = summed[name]
            elif isinstance(v, (int, float)) \
                    and isinstance(first.get(name), (int, float)):
                d = v - first.get(name, 0)
            else:
                d = None
            delta = f" (+{d:g})" if d else ""
            print(f"  {name:<44s} {v:g}{delta}")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--snapshot", metavar="FILE.jsonl",
                    help="report on a registry JSONL export instead of "
                         "running the q5 smoke pipeline")
    ap.add_argument("--grep", default="",
                    help="with --snapshot: only metrics containing this")
    ap.add_argument("--timeline", action="store_true",
                    help="run the smoke pipeline with the temporal plane "
                         "enabled and print per-interval series + alerts")
    ap.add_argument("--since", type=float, default=None,
                    help="with --timeline: drop intervals ending before "
                         "this logical time (s)")
    ap.add_argument("--until", type=float, default=None,
                    help="with --timeline: drop intervals ending after "
                         "this logical time (s)")
    ap.add_argument("--interval", type=float, default=0.1,
                    help="with --timeline: interval width on the "
                         "logical clock (s)")
    ap.add_argument("--query", choices=("q5", "q20"), default="q5",
                    help="smoke pipeline to run (q5 sliding windows or "
                         "q20 stateful filter-join)")
    ap.add_argument("--duration", type=float, default=3.0)
    ap.add_argument("--warmup", type=float, default=1.5)
    ap.add_argument("--sample-every", type=int, default=16)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--fused", action="store_true",
                    help="run the q5 smoke pipeline on the fused device "
                         "hot path and report its batch-fill ratio")
    ap.add_argument("--export", metavar="FILE.jsonl",
                    help="also append registry snapshots during the run "
                         "(with --timeline: the timeline JSONL instead)")
    args = ap.parse_args()
    if args.snapshot:
        return snapshot_report(args.snapshot, args.grep)
    if args.timeline:
        return timeline_report(args)
    return run_report(args)


if __name__ == "__main__":
    sys.exit(main())
