#!/usr/bin/env python
"""Chaos/soak driver for the exactly-once state-effect oracle
(streaming/chaos.py, DESIGN.md §15).

Each schedule perturbs the NEXMark q11 session query with >= 2
concurrent fault kinds (failure, shard migration, load shift, hint-
channel drop/delay) and differentially compares final keyed state,
session registry, and per-pane final emits against an unperturbed
golden run of the same workload seed.  Failing schedules are shrunk to
a minimal reproducer and pickled under ``--out-dir``.

  --smoke          3 fixed-seed schedules (the CI gate)
  --soak N         N schedules from a rotating base seed (nightly)
  --seed B         base seed for --soak (e.g. the CI run number)

Exit status 1 iff any schedule violates the oracle.
"""
from __future__ import annotations

import argparse
import sys

sys.path.insert(0, "src")

from repro.streaming.chaos import (FaultSchedule, check_schedule,  # noqa: E402
                                   minimize, save_artifact)

SMOKE_SEEDS = (101, 202, 303)


def run_one(sched: FaultSchedule, t_cut: float, out_dir: str,
            golden_cache: dict) -> bool:
    golden = golden_cache.get(sched.seed)
    report, golden, perturbed = check_schedule(sched, t_cut, golden=golden)
    golden_cache[sched.seed] = golden
    status = "ok" if report.ok else "VIOLATED"
    print(f"seed {sched.seed} kinds={'/'.join(sched.kinds())}: {status} "
          f"deviations={report.deviations} "
          f"(fires={perturbed.metrics['fires']} "
          f"merged={perturbed.metrics['sessions_merged']} "
          f"failures={perturbed.metrics['failures']})")
    if report.ok:
        return True
    for v in report.violations[:5]:
        print(f"  violation: {v}")
    mini = minimize(sched, t_cut, golden=golden)
    path = save_artifact(mini, report, out_dir=out_dir)
    print(f"  minimized to {len(mini.events)} event(s): {mini.events}")
    print(f"  reproducer pickled: {path}")
    return False


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    g = ap.add_mutually_exclusive_group(required=True)
    g.add_argument("--smoke", action="store_true",
                   help="3 fixed-seed schedules (CI gate)")
    g.add_argument("--soak", type=int, metavar="N",
                   help="N rotating-seed schedules (nightly)")
    ap.add_argument("--seed", type=int, default=0,
                    help="base seed for --soak schedules")
    ap.add_argument("--t-cut", type=float, default=2.0,
                    help="logical stream length per run (seconds)")
    ap.add_argument("--events", type=int, default=4,
                    help="fault events per schedule")
    ap.add_argument("--out-dir", default="chaos_artifacts",
                    help="directory for minimized reproducer pickles")
    args = ap.parse_args()

    if args.smoke:
        seeds = SMOKE_SEEDS
    else:
        seeds = tuple(1000 + args.seed * 17 + i for i in range(args.soak))

    golden_cache: dict = {}
    failures = 0
    for seed in seeds:
        sched = FaultSchedule.random(seed, n_events=args.events)
        if not run_one(sched, args.t_cut, args.out_dir, golden_cache):
            failures += 1
    total = len(seeds)
    print(f"\n{total - failures}/{total} schedules passed the "
          f"exactly-once oracle")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
