"""End-to-end training driver with fault tolerance: trains a reduced
gemma-family model on the deterministic token pipeline, injects a node
failure mid-run, and recovers from the latest checkpoint.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 120]
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.checkpoint.manager import CheckpointManager
from repro.launch.train import build_training
from repro.runtime.supervisor import (SupervisorConfig, TrainSupervisor,
                                      inject_failure_at)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--arch", default="gemma-7b")
    args = ap.parse_args()

    state, step_fn, model, cfg = build_training(
        args.arch, smoke=True, batch=8, seq=64, n_micro=2, compress=False)
    with tempfile.TemporaryDirectory() as d:
        ckpt = CheckpointManager(d, keep=2)
        sup = TrainSupervisor(SupervisorConfig(checkpoint_every=20), ckpt)
        fail_at = args.steps // 2
        print(f"training {args.arch} (reduced) for {args.steps} steps, "
              f"failure injected at step {fail_at}")
        rep = sup.run(state, step_fn, args.steps,
                      failure_injector=inject_failure_at({fail_at}))
        print(f"steps run (incl. replayed): {rep.steps_run}, "
              f"restarts: {rep.restarts}")
        print(f"loss: {rep.losses[0]:.3f} -> {rep.losses[-1]:.3f}")
        assert rep.restarts == 1 and rep.losses[-1] < rep.losses[0]
        print("recovered and converged ✓")


if __name__ == "__main__":
    main()
