"""Serve a small model with batched requests while Keyed Prefetching stages
multi-turn session state (see repro/launch/serve.py for the machinery).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main

if __name__ == "__main__":
    main()
