"""Quickstart: the paper's Fig-1 fraud-detection pipeline.

A stream of card transactions goes through attribute extraction and
normalization before a risk-assessment operator that reads per-card state
from a (modelled) NVMe-backed key-value store.  Keyed Prefetching extracts
the card id at the attribute-extraction operator (the lookahead), sends
hints on a side channel, and the Timestamp-Aware Cache stages the card state
before the transaction arrives.

Run:  PYTHONPATH=src python examples/quickstart.py

The fault-tolerance plane (DESIGN.md §7) is runnable from here too:

    PYTHONPATH=src python examples/quickstart.py --fail-at 3.0 --recover warmed

takes barrier-aligned checkpoints, kills the job mid-run, and recovers
from the last completed epoch — ``warmed`` replays the logged hint
stream to pre-stage the hot cards before the data replay, ``cold`` shows
the on-demand post-restore latency spike it avoids.
"""
import argparse
import os
import random
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.streaming.backend import LOCAL_NVME
from repro.streaming.engine import (Engine, MapOp, SinkOp, SourceOp,
                                    StatefulOp)
from repro.streaming.events import Tuple_


def build(policy: str, mode: str, replayable: bool = False,
          fused: bool = False) -> Engine:
    eng = Engine()
    rng = random.Random(1)
    n_cards = 200_000

    def gen(now):
        # 30% of traffic from a rotating set of hot cards
        if rng.random() < 0.3:
            card = int(now) * 50 + rng.randint(0, 49)
        else:
            card = rng.randint(0, n_cards - 1)
        return (card, {"card": card, "amount": rng.random() * 500}, 180)

    def key_of(tup):
        return tup.payload["card"]

    def risk(tup, state):
        hist = dict(state or {"n": 0, "total": 0.0})
        hist["n"] += 1
        hist["total"] += tup.payload["amount"]
        score = tup.payload["amount"] / (1 + hist["total"] / hist["n"])
        return hist, [Tuple_(tup.ts, tup.key, {"score": score}, 64,
                             tup.ingest_t)]

    fused_kw = {}
    if fused:
        # declarative device form of risk() (DESIGN.md §14): state is
        # the [count, total] pair, each transaction adds [1, amount],
        # and the score emit reads the composed post-update value
        from repro.streaming.fused import FusedSpec

        def score_of(tup, hist):
            amount = tup.payload["amount"]
            score = amount / (1 + hist["total"] / hist["n"])
            return [Tuple_(tup.ts, tup.key, {"score": score}, 64,
                           tup.ingest_t)]

        fused_kw = dict(fused=FusedSpec(
            kind="sum", width=2,
            weight_of=lambda tup: [1.0, tup.payload["amount"]],
            encode=lambda s: None if s is None
            else [float(s["n"]), float(s["total"])],
            decode=lambda v: {"n": int(round(float(v[0]))),
                              "total": float(v[1])},
            emit_of=score_of))

    src = eng.add(SourceOp(eng, "source", 1, 20_000, gen,
                           replayable=replayable))
    extract = eng.add(MapOp(eng, "extract", 2, service_time=12e-6,
                            key_of=key_of))
    normalize = eng.add(MapOp(eng, "normalize", 2, service_time=8e-6,
                              key_of=key_of))
    assess = eng.add(StatefulOp(eng, "stateful", 2, risk, LOCAL_NVME,
                                cache_capacity=512 * 300, policy=policy,
                                mode=mode, io_workers=3, state_size=300,
                                default_state=lambda k: {"n": 0,
                                                         "total": 0.0},
                                **fused_kw))
    sink = eng.add(SinkOp(eng, "sink", 1))
    eng.connect(src, extract)
    eng.connect(extract, normalize)
    eng.connect(normalize, assess)
    eng.connect(assess, sink, partition=lambda k, n: 0)
    if mode == "prefetch":
        eng.register_prefetching(assess, [extract, normalize])
    return eng


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fail-at", type=float, default=None,
                    help="inject a whole-job failure this many seconds "
                         "AFTER warmup (same clock as benchmarks/run.py "
                         "--fail-at); enables checkpointing + replayable "
                         "source")
    ap.add_argument("--recover", choices=("warmed", "cold"),
                    default="warmed",
                    help="recovery mode after --fail-at: 'warmed' replays "
                         "the hint log before the data path resumes")
    ap.add_argument("--checkpoint-interval", type=float, default=0.5)
    ap.add_argument("--fused", action="store_true",
                    help="add a fused device-path run (DESIGN.md §14): "
                         "the risk operator's probe/update/emit loop "
                         "compiles to one jitted program per batch")
    args = ap.parse_args()

    if args.fail_at is not None:
        from repro.streaming.recovery import (CheckpointCoordinator,
                                              inject_failure_at)
        warmup = 1.0
        t_fail = warmup + args.fail_at
        print(f"fraud-detection quickstart with a failure "
              f"{args.fail_at}s after warmup ({args.recover} recovery)")
        eng = build("tac", "prefetch", replayable=True)
        coord = CheckpointCoordinator(eng,
                                      interval=args.checkpoint_interval)
        coord.start()
        inject_failure_at(eng, at=t_fail, mode=args.recover)
        m = eng.run(duration=max(6.0, args.fail_at + 3.0), warmup=warmup)
        ck, rec = m.get("checkpoint", {}), m.get("recovery", {})
        print(f"  p50={m['p50']*1e3:7.2f}ms p999={m['p999']*1e3:8.2f}ms "
              f"cache-hit={m.get('stateful_hit_rate', 0):.3f}")
        print(f"  epochs completed={ck.get('epochs_completed')} "
              f"align-stall max={ck.get('align_stall_max', 0)*1e3:.2f}ms")
        print(f"  recovered from epoch {rec.get('last_epoch')} in "
              f"{rec.get('last_downtime', 0)*1e3:.1f}ms "
              f"(restore {rec.get('last_restore_bytes', 0)} B, "
              f"{rec.get('warmup_hints', 0)} warmup hints, "
              f"{rec.get('replayed', 0)} tuples replayed)")
        return

    print("fraud-detection quickstart (6s simulated stream, 20k tx/s)")
    runs = [("cache-only (sync)", "lru", "sync", False),
            ("async I/O", "lru", "async", False),
            ("keyed prefetching", "tac", "prefetch", False)]
    if args.fused:
        runs.append(("fused device path", "tac", "prefetch", True))
    for label, policy, mode, fused in runs:
        m = build(policy, mode, fused=fused).run(duration=5.0, warmup=2.0)
        fill = m.get("stateful_fused", {}).get("fill_ratio")
        extra = f" batch-fill={fill:.2f}" if fill is not None else ""
        print(f"  {label:22s} p50={m['p50']*1e3:7.2f}ms "
              f"p999={m['p999']*1e3:8.2f}ms "
              f"cache-hit={m.get('stateful_hit_rate', 0):.3f}{extra}")


if __name__ == "__main__":
    main()
