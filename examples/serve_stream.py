"""Streaming tour of the paged session-state serving subsystem.

Drives ``repro.serving`` directly with a synthetic decode step (no model
compile), so the arena / tiered-store / scheduler interplay is visible in
isolation: requests arrive, the ingest stage hints the store, pages stream
toward the arena, and only page-resident requests are scheduled.

    PYTHONPATH=src python examples/serve_stream.py --mode prefetch
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp
import numpy as np

from repro.serving import (ContinuousBatchingScheduler, PagedStateArena,
                           Request, SimClock, TieredStore)

PAGE, D, PAGES_PER_SESSION = 16, 8, 3


def page_keys(sid: int) -> np.ndarray:
    return np.asarray([sid * 64 + p + 1 for p in range(PAGES_PER_SESSION)],
                      np.int32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="prefetch",
                    choices=["sync", "async", "prefetch"])
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--sessions", type=int, default=8)
    ap.add_argument("--rate", type=float, default=300.0)
    args = ap.parse_args()

    arena = PagedStateArena(n_buckets=8, ways=4,
                            pools={"state": ((PAGE, D), jnp.float32)})
    store = TieredStore(page_bytes=PAGE * D * 4, workers=4)
    rng = np.random.RandomState(0)
    for sid in range(args.sessions):
        for p, key in enumerate(page_keys(sid)):
            store.seed(int(key),
                       {"state": rng.randn(PAGE, D).astype(np.float32)})

    clock = SimClock()
    sched = ContinuousBatchingScheduler(arena, store, mode=args.mode,
                                        max_batch=2, clock=clock)
    arrivals = np.cumsum(rng.exponential(1.0 / args.rate, args.requests))
    reqs = [Request(rid=i, session=int(rng.randint(args.sessions)),
                    page_keys=None, n_tokens=3) for i in range(args.requests)]
    for r in reqs:
        r.page_keys = page_keys(r.session)

    i = 0
    while i < args.requests or sched.pending:
        while i < args.requests and arrivals[i] <= clock.now():
            sched.submit(reqs[i])
            print(f"{clock.now()*1e3:8.2f}ms  enqueue r{reqs[i].rid} "
                  f"(session {reqs[i].session})")
            i += 1
        batch = sched.schedule()
        if not batch:
            if sched.wait_for_progress():
                continue
            if i < args.requests:
                clock.sleep(max(1e-6, arrivals[i] - clock.now()))
                continue
            break
        for req in batch:
            clock.advance(0.8e-3)               # synthetic decode step
            sched.complete_token(req, dirty_keys=req.page_keys[:1])
            tag = "FIRST" if req.tokens_done == 1 else f"tok{req.tokens_done}"
            print(f"{clock.now()*1e3:8.2f}ms  decode  r{req.rid} {tag}"
                  + ("  [done]" if req.state == "done" else ""))

    s = sched.stats()
    print(f"\n[{args.mode}] ttft p50={s['ttft_p50']*1e3:.2f}ms "
          f"p99={s['ttft_p99']*1e3:.2f}ms  arena hit={s['arena_hit_rate']:.2f}"
          f"  staging overlap={s['staging_overlap']:.2f}  "
          f"writebacks={s['store_writebacks']}")


if __name__ == "__main__":
    main()
